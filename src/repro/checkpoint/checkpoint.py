"""Atomic, async, retention-managed checkpoints for arbitrary pytrees.

Layout:  <dir>/step_<n>/   arrays.npz  +  manifest.json (treedef + dtypes)
Writes go to a temp dir and are renamed atomically; an optional background
thread overlaps serialization with training.  Restore reshards onto any mesh
by ``jax.device_put``-ing full arrays against target shardings — this is the
elastic-rescale path (the mesh/DP degree may differ from the writer's).

Quantized cache slots (int8 + scales) round-trip transparently since they are
registered pytree nodes.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np
from jax import numpy as jnp

_BF16 = np.dtype(jnp.bfloat16.dtype)


def _encode(a: np.ndarray):
    """npz cannot store ml_dtypes; view bf16 as uint16 and tag it."""
    if a.dtype == _BF16:
        return a.view(np.uint16), "bfloat16"
    return a, str(a.dtype)


def _decode(a: np.ndarray, dtype: str) -> np.ndarray:
    if dtype == "bfloat16":
        return a.view(_BF16)
    return a


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Blocking atomic save.  Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves, _ = _flatten_with_paths(tree)
    encoded = [_encode(np.asarray(l)) for l in leaves]
    arrays = {f"a{i}": a for i, (a, _) in enumerate(encoded)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "paths": paths,
        "dtypes": [d for _, d in encoded],
        "time": time.time(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_checkpoint(directory: str) -> str | None:
    if not os.path.isdir(directory):
        return None
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    return os.path.join(directory, steps[-1]) if steps else None


def restore_checkpoint(
    path: str,
    like: Any,
    shardings: Any | None = None,
) -> Any:
    """Restore into the structure of ``like``; optionally reshard onto
    ``shardings`` (a matching tree of NamedSharding) — the elastic path."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as data:
        arrays = [
            _decode(data[f"a{i}"], manifest["dtypes"][i])
            for i in range(len(data.files))
        ]
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    if len(arrays) != len(flat_like):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, expected {len(flat_like)}"
        )
    for a, l in zip(arrays, flat_like):
        if tuple(a.shape) != tuple(l.shape):
            raise ValueError(f"shape mismatch {a.shape} vs {l.shape}")
    restored = jax.tree_util.tree_unflatten(treedef, arrays)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings
        )
    return restored


class CheckpointManager:
    """Async save + retention.  ``save`` returns immediately; the previous
    in-flight save is joined first (at most one outstanding write)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.saved_steps: list[int] = []

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            d for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for d in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, d), ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        self.wait()
        # materialize on host before handing to the writer thread
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def run():
            save_checkpoint(self.directory, step, host_tree)
            self._gc()

        if blocking:
            run()
        else:
            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()
        self.saved_steps.append(step)

    def restore_latest(self, like: Any, shardings: Any | None = None):
        self.wait()
        path = latest_checkpoint(self.directory)
        if path is None:
            return None, -1
        with open(os.path.join(path, "manifest.json")) as f:
            step = json.load(f)["step"]
        return restore_checkpoint(path, like, shardings), step
