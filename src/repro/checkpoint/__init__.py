"""Fault-tolerant checkpointing."""

from repro.checkpoint.checkpoint import (
    CheckpointManager,
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = [
    "CheckpointManager",
    "latest_checkpoint",
    "restore_checkpoint",
    "save_checkpoint",
]
