"""Decoder-only LM covering the dense / MoE / SSM / hybrid families.

Layers are scanned (stacked parameters) so the HLO stays O(1) in depth, with
configurable activation rematerialization.  The hybrid family (zamba2) scans
groups of `attn_every` Mamba2 layers with a single *shared* attention block
applied between groups.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    ParamDecl,
    embed_decls,
    embed_lookup,
    mlp_apply,
    mlp_decls,
    norm_decls,
    apply_norm,
    round_up,
    unembed,
)
from repro.models.sharding import shard_batch

AUX_LOSS_COEF = 0.01


def stack_decls(decls, n: int):
    return jax.tree.map(
        lambda d: ParamDecl((n,) + d.shape, ("layers",) + d.logical, d.init, d.dtype),
        decls,
        is_leaf=lambda x: isinstance(x, ParamDecl),
    )


def padded_kv_heads(cfg: ModelConfig) -> int:
    return round_up(cfg.num_kv_heads, max(cfg.kv_pad_to, 1))


def padded_heads(cfg: ModelConfig) -> int:
    """Query-head count padded to the TP degree (cfg.head_pad_to; production
    configs use 16, smoke configs 1).  See DESIGN.md §6."""
    return round_up(cfg.num_heads, max(cfg.head_pad_to, 1))


# ---------------------------------------------------------------------------
# Per-layer block
# ---------------------------------------------------------------------------


def _block_decls(cfg: ModelConfig) -> dict[str, Any]:
    """One residual block of the *stacked* part of the model."""
    if cfg.family == "ssm" or (cfg.family == "hybrid"):
        return {"ln": norm_decls(cfg), "mamba": ssm_mod.mamba_decls(cfg)}
    out: dict[str, Any] = {"ln1": norm_decls(cfg), "ln2": norm_decls(cfg)}
    if cfg.use_mla:
        out["attn"] = attn.mla_decls(cfg)
    else:
        out["attn"] = attn.gqa_decls(cfg, heads=padded_heads(cfg))
    if cfg.num_experts:
        out["moe"] = moe_mod.moe_decls(cfg)
    else:
        out["mlp"] = mlp_decls(cfg, swiglu=cfg.mlp_swiglu)
    return out


def _shared_attn_decls(cfg: ModelConfig) -> dict[str, Any]:
    """zamba2: one shared full attention+MLP block used every attn_every
    layers (weights shared across its invocations)."""
    return {
        "ln1": norm_decls(cfg),
        "attn": attn.gqa_decls(cfg, heads=padded_heads(cfg)),
        "ln2": norm_decls(cfg),
        "mlp": mlp_decls(cfg, swiglu=True),
    }


def lm_decls(cfg: ModelConfig) -> dict[str, Any]:
    decls: dict[str, Any] = {
        "embed": embed_decls(cfg),
        "blocks": stack_decls(_block_decls(cfg), cfg.num_layers),
        "ln_f": norm_decls(cfg),
    }
    if cfg.family == "hybrid":
        decls["shared_attn"] = _shared_attn_decls(cfg)
    if cfg.max_position_embeddings:
        decls["pos"] = ParamDecl(
            (cfg.max_position_embeddings, cfg.d_model), ("pos", "embed")
        )
    return decls


# ---------------------------------------------------------------------------
# Block application (full-sequence)
# ---------------------------------------------------------------------------


def _apply_block(cfg: ModelConfig, bp, x, positions):
    """Full-sequence residual block.  Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family in ("ssm", "hybrid"):
        h = apply_norm(cfg, bp["ln"], x)
        x = x + ssm_mod.mamba_forward(cfg, bp["mamba"], h)
        return x, aux
    h = apply_norm(cfg, bp["ln1"], x)
    if cfg.use_mla:
        x = x + attn.mla_forward(cfg, bp["attn"], h, positions)
    else:
        x = x + attn.gqa_forward(cfg, bp["attn"], h, positions, use_rope=not cfg.max_position_embeddings)
    h = apply_norm(cfg, bp["ln2"], x)
    if cfg.num_experts:
        y, aux = moe_mod.moe_apply(cfg, bp["moe"], h)
        x = x + y
    else:
        x = x + mlp_apply(bp["mlp"], h, swiglu=cfg.mlp_swiglu)
    return x, aux


def _apply_shared_attn(cfg: ModelConfig, sp, x, positions):
    h = apply_norm(cfg, sp["ln1"], x)
    x = x + attn.gqa_forward(cfg, sp["attn"], h, positions)
    h = apply_norm(cfg, sp["ln2"], x)
    return x + mlp_apply(sp["mlp"], h, swiglu=True)


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "selective":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def backbone_forward(
    cfg: ModelConfig,
    params,
    x: jnp.ndarray,  # [b, s, d] embedded inputs
    positions: jnp.ndarray,
    *,
    remat: str = "full",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Run all blocks (scan over stacked layers).  Returns (x, aux_loss)."""
    x = shard_batch(x, None, None)

    if cfg.family == "hybrid":
        k = cfg.attn_every
        groups = cfg.num_layers // k

        def group_body(carry, gp):
            xx, aux = carry
            mamba_stack, shared = gp, params["shared_attn"]

            def layer_body(c, lp):
                y, a = _apply_block(cfg, lp, c[0], positions)
                return (y, c[1] + a), None

            (xx, aux), _ = jax.lax.scan(layer_body, (xx, aux), mamba_stack)
            xx = _apply_shared_attn(cfg, shared, xx, positions)
            return (xx, aux), None

        stacked = jax.tree.map(
            lambda a: a.reshape((groups, k) + a.shape[1:]), params["blocks"]
        )
        body = _remat(group_body, remat)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stacked)
        return x, aux

    def body(carry, lp):
        xx, aux = carry
        y, a = _apply_block(cfg, lp, xx, positions)
        return (y, aux + a), None

    body = _remat(body, remat)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    return x, aux


# ---------------------------------------------------------------------------
# Embedding / loss heads
# ---------------------------------------------------------------------------


def embed_inputs(cfg: ModelConfig, params, tokens, *, image_embed=None, offset=0):
    dtype = jnp.dtype(cfg.dtype)
    x = embed_lookup(params["embed"], tokens, cfg.d_model, dtype)
    if image_embed is not None:  # pixtral: image patches prefix the text
        x = jnp.concatenate([image_embed.astype(dtype), x], axis=1)
    if cfg.max_position_embeddings:
        s = x.shape[1]
        pos_table = jax.lax.dynamic_slice_in_dim(params["pos"], offset, s, 0)
        x = x + pos_table[None].astype(dtype)
    return x


def lm_logits(cfg: ModelConfig, params, x):
    logits = unembed(cfg, params["embed"], x)
    return shard_batch(logits, None, "model")


def fused_next_token_loss(
    cfg: ModelConfig, params, x, tokens, *, text_offset: int = 0, chunk: int = 8192
):
    """Cross-entropy fused with the unembedding matmul, chunked over vocab.

    Never materializes [b, s, V] logits: a lax.scan over vocab chunks keeps a
    running (max, sumexp) pair plus the target logit, so HBM traffic per step
    is [b, s, chunk] instead of the full logit tensor (the dominant memory
    term for 150k-vocab models — see EXPERIMENTS.md §Perf)."""
    if text_offset:
        x = x[:, text_offset:]
    xs = x[:, :-1]
    targets = tokens[:, 1:]
    if cfg.tie_embeddings:
        w = params["embed"]["tok"].T  # [d, V]
    else:
        w = params["embed"]["unembed"]
    v = w.shape[1]
    if v % chunk:
        chunk = v  # fallback: single chunk (smoke configs)
    nc = v // chunk
    wc = w.reshape(w.shape[0], nc, chunk).transpose(1, 0, 2)  # [nc, d, chunk]

    def body(carry, args):
        m, s, tl = carry
        ci, w_blk = args
        logits = jnp.einsum(
            "bsd,dv->bsv", xs, w_blk.astype(xs.dtype)
        ).astype(jnp.float32)
        m_new = jnp.maximum(m, logits.max(-1))
        s = s * jnp.exp(m - m_new) + jnp.exp(
            logits - m_new[..., None]
        ).sum(-1)
        # target logit if it falls inside this chunk
        local = targets - ci * chunk
        hit = (local >= 0) & (local < chunk)
        got = jnp.take_along_axis(
            logits, jnp.clip(local, 0, chunk - 1)[..., None], axis=-1
        )[..., 0]
        tl = jnp.where(hit, got, tl)
        return (m_new, s, tl), None

    b, sm1 = targets.shape
    init = (
        jnp.full((b, sm1), -1e30, jnp.float32),
        jnp.zeros((b, sm1), jnp.float32),
        jnp.zeros((b, sm1), jnp.float32),
    )
    (m, s, tl), _ = jax.lax.scan(
        jax.checkpoint(body), init, (jnp.arange(nc), wc)
    )
    return jnp.mean(jnp.log(s) + m - tl)


def next_token_loss(cfg: ModelConfig, logits, tokens, *, text_offset: int = 0):
    """Cross-entropy of logits[:, t] against tokens[:, t+1]."""
    if text_offset:
        logits = logits[:, text_offset:]
    pred = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    lse = jax.scipy.special.logsumexp(pred, axis=-1)
    true_logit = jnp.take_along_axis(pred, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - true_logit)
