"""Unified model API over all assigned architectures.

``build_model(cfg)`` returns a :class:`Model` exposing:

  * ``init(key)`` / ``abstract()`` / ``param_specs(fsdp)``
  * ``train_loss(params, batch, remat)``   (next-token CE + MoE aux)
  * ``prefill(params, batch, cache_len)`` -> (last_logits, cache)
  * ``decode_step(params, tokens, cache, index)`` -> (logits, cache)
  * ``cache_abstract(shape)`` / ``cache_specs()``  (dry-run serving inputs)

Batch layouts:
  LM:      {"tokens": [b, s] int32}
  enc-dec: {"tokens": [b, s], "audio_embed": [b, enc_seq, d]}   (stub frontend)
  VLM:     {"tokens": [b, s - n_img], "image_embed": [b, n_img, d]}
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    ParamDecl,
    abstract_from_decls,
    apply_norm,
    embed_decls,
    init_from_decls,
    make_rules,
    mlp_apply,
    mlp_decls,
    norm_decls,
    specs_from_decls,
)
from repro.models.sharding import batch_spec, dp_axes, shard_batch
from repro.models.transformer import (
    AUX_LOSS_COEF,
    fused_next_token_loss,
    padded_kv_heads,
    _apply_block,
    _apply_shared_attn,
    _remat,
    backbone_forward,
    embed_inputs,
    lm_decls,
    lm_logits,
    next_token_loss,
    padded_heads,
    stack_decls,
)

# ---------------------------------------------------------------------------
# Whisper-style encoder-decoder declarations
# ---------------------------------------------------------------------------


def encdec_decls(cfg: ModelConfig) -> dict[str, Any]:
    enc_block = {
        "ln1": norm_decls(cfg),
        "attn": attn.gqa_decls(cfg, heads=padded_heads(cfg)),
        "ln2": norm_decls(cfg),
        "mlp": mlp_decls(cfg, swiglu=False),
    }
    dec_block = {
        "ln1": norm_decls(cfg),
        "attn": attn.gqa_decls(cfg, heads=padded_heads(cfg)),
        "ln_x": norm_decls(cfg),
        "cross": attn.gqa_decls(cfg, heads=padded_heads(cfg)),
        "ln2": norm_decls(cfg),
        "mlp": mlp_decls(cfg, swiglu=False),
    }
    return {
        "embed": embed_decls(cfg),
        "enc_pos": ParamDecl((cfg.encoder_seq, cfg.d_model), ("pos", "embed")),
        "pos": ParamDecl((cfg.max_position_embeddings, cfg.d_model), ("pos", "embed")),
        "enc_blocks": stack_decls(enc_block, cfg.encoder_layers),
        "enc_ln_f": norm_decls(cfg),
        "blocks": stack_decls(dec_block, cfg.num_layers),
        "ln_f": norm_decls(cfg),
    }


def _encode(cfg: ModelConfig, params, audio_embed):
    dtype = jnp.dtype(cfg.dtype)
    x = audio_embed.astype(dtype) + params["enc_pos"][None].astype(dtype)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    def body(carry, lp):
        h = apply_norm(cfg, lp["ln1"], carry)
        carry = carry + attn.gqa_forward(cfg, lp["attn"], h, positions, causal=False, use_rope=False)
        h = apply_norm(cfg, lp["ln2"], carry)
        carry = carry + mlp_apply(lp["mlp"], h, swiglu=False)
        return carry, None

    x, _ = jax.lax.scan(_remat(body, "full"), x, params["enc_blocks"])
    return apply_norm(cfg, params["enc_ln_f"], x)


def _encdec_decoder(cfg, params, x, positions, enc_out, remat):
    """Full-sequence decoder pass (train/prefill)."""

    def body(carry, lp):
        h = apply_norm(cfg, lp["ln1"], carry)
        carry = carry + attn.gqa_forward(cfg, lp["attn"], h, positions, causal=True, use_rope=False)
        h = apply_norm(cfg, lp["ln_x"], carry)
        ek, ev = attn.encoder_kv(cfg, lp["cross"], enc_out)
        carry = carry + attn.cross_attention_forward(cfg, lp["cross"], h, ek, ev)
        h = apply_norm(cfg, lp["ln2"], carry)
        carry = carry + mlp_apply(lp["mlp"], h, swiglu=False)
        return carry, None

    x, _ = jax.lax.scan(_remat(body, remat), x, params["blocks"])
    return apply_norm(cfg, params["ln_f"], x)


# ---------------------------------------------------------------------------
# KV-cache construction
# ---------------------------------------------------------------------------


def cache_abstract(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    """ShapeDtypeStructs of the decode cache for this architecture."""
    dt = jnp.dtype(dtype or cfg.dtype)
    L, b, S = cfg.num_layers, batch, cache_len
    hd = cfg.resolved_head_dim
    kvh = padded_kv_heads(cfg)

    def sd(shape, d=dt):
        return jax.ShapeDtypeStruct(shape, d)

    if cfg.family == "enc_dec":
        enc = cfg.encoder_seq
        return {
            "k": sd((L, b, S, kvh, hd)),
            "v": sd((L, b, S, kvh, hd)),
            "cross_k": sd((L, b, enc, kvh, hd)),
            "cross_v": sd((L, b, enc, kvh, hd)),
        }
    if cfg.use_mla:
        return {
            "c_kv": sd((L, b, S, cfg.kv_lora_rank)),
            "k_rope": sd((L, b, S, cfg.qk_rope_dim)),
        }
    if cfg.family == "ssm":
        d_inner, h, n = ssm_mod.ssm_dims(cfg)
        c = cfg.ssm_conv - 1
        return {
            "state": sd((L, b, h, cfg.ssm_head_dim, n), jnp.float32),
            "conv": {
                "x": sd((L, b, c, d_inner)),
                "B": sd((L, b, c, ssm_mod.N_GROUPS * n)),
                "C": sd((L, b, c, ssm_mod.N_GROUPS * n)),
            },
        }
    if cfg.family == "hybrid":
        d_inner, h, n = ssm_mod.ssm_dims(cfg)
        c = cfg.ssm_conv - 1
        groups = cfg.num_layers // cfg.attn_every
        return {
            "mamba": {
                "state": sd((L, b, h, cfg.ssm_head_dim, n), jnp.float32),
                "conv": {
                    "x": sd((L, b, c, d_inner)),
                    "B": sd((L, b, c, ssm_mod.N_GROUPS * n)),
                    "C": sd((L, b, c, ssm_mod.N_GROUPS * n)),
                },
            },
            "shared": {
                "k": sd((groups, b, S, kvh, hd)),
                "v": sd((groups, b, S, kvh, hd)),
            },
        }
    return {"k": sd((L, b, S, kvh, hd)), "v": sd((L, b, S, kvh, hd))}


def cache_specs(cfg: ModelConfig) -> Any:
    """PartitionSpec tree matching :func:`cache_abstract`."""
    dp = dp_axes()
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    kv = P(None, dp, "model", None, None)
    if cfg.family == "enc_dec":
        cross = P(None, dp, None, None, None)  # enc_seq (1500) not shardable
        return {"k": kv, "v": kv, "cross_k": cross, "cross_v": cross}
    if cfg.use_mla:
        return {"c_kv": P(None, dp, "model", None), "k_rope": P(None, dp, "model", None)}
    ssm_spec = {
        "state": P(None, dp, "model", None, None),
        "conv": {
            "x": P(None, dp, None, "model"),
            "B": P(None, dp, None, None),
            "C": P(None, dp, None, None),
        },
    }
    if cfg.family == "ssm":
        return ssm_spec
    if cfg.family == "hybrid":
        return {"mamba": ssm_spec, "shared": {"k": kv, "v": kv}}
    return {"k": kv, "v": kv}


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    def __post_init__(self):
        self.decls = (
            encdec_decls(self.cfg) if self.cfg.family == "enc_dec" else lm_decls(self.cfg)
        )

    # -- parameters -------------------------------------------------------
    def init(self, key) -> Any:
        return init_from_decls(self.decls, key, jnp.dtype(self.cfg.dtype))

    def abstract(self) -> Any:
        return abstract_from_decls(self.decls, jnp.dtype(self.cfg.dtype))

    def param_specs(self, fsdp: bool = False) -> Any:
        return specs_from_decls(self.decls, make_rules(self.cfg, fsdp))

    def num_params(self) -> int:
        import math

        return sum(math.prod(l.shape) for l in jax.tree.leaves(self.abstract()))

    # -- training ----------------------------------------------------------
    def train_loss(
        self, params, batch, *, remat: str = "full", fused_loss: bool = False
    ) -> jnp.ndarray:
        cfg = self.cfg
        tokens = batch["tokens"]
        if cfg.family == "enc_dec":
            enc_out = _encode(cfg, params, batch["audio_embed"])
            x = embed_inputs(cfg, params, tokens)
            positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
            x = _encdec_decoder(cfg, params, x, positions, enc_out, remat)
            if fused_loss:
                return fused_next_token_loss(cfg, params, x, tokens)
            logits = lm_logits(cfg, params, x)
            return next_token_loss(cfg, logits, tokens)
        image = batch.get("image_embed") if isinstance(batch, dict) else None
        x = embed_inputs(cfg, params, tokens, image_embed=image)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x, aux = backbone_forward(cfg, params, x, positions, remat=remat)
        x = apply_norm(cfg, params["ln_f"], x)
        offset = cfg.num_image_tokens if image is not None else 0
        if fused_loss:
            ce = fused_next_token_loss(cfg, params, x, tokens, text_offset=offset)
        else:
            logits = lm_logits(cfg, params, x)
            ce = next_token_loss(cfg, logits, tokens, text_offset=offset)
        return ce + (AUX_LOSS_COEF * aux if cfg.num_experts else 0.0)

    # -- serving: prefill ---------------------------------------------------
    def prefill(self, params, batch, cache_len: int):
        cfg = self.cfg
        tokens = batch["tokens"]
        b = tokens.shape[0]
        if cfg.family == "enc_dec":
            return self._prefill_encdec(params, batch, cache_len)
        image = batch.get("image_embed") if isinstance(batch, dict) else None
        x = embed_inputs(cfg, params, tokens, image_embed=image)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x = shard_batch(x, None, None)

        if cfg.family in ("ssm", "hybrid"):
            x, cache = self._prefill_recurrent(params, x, positions, cache_len)
        else:
            def body(carry, lp):
                h = apply_norm(cfg, lp["ln1"], carry)
                if cfg.use_mla:
                    y, c = attn.mla_prefill_with_cache(cfg, lp["attn"], h, positions, cache_len)
                else:
                    y, c = attn.gqa_prefill_with_cache(cfg, lp["attn"], h, positions, cache_len)
                carry = carry + y
                h = apply_norm(cfg, lp["ln2"], carry)
                if cfg.num_experts:
                    yy, _ = moe_mod.moe_apply(cfg, lp["moe"], h)
                    carry = carry + yy
                else:
                    carry = carry + mlp_apply(lp["mlp"], h, swiglu=cfg.mlp_swiglu)
                return carry, c

            x, cache = jax.lax.scan(body, x, params["blocks"])
        x = apply_norm(cfg, params["ln_f"], x)
        logits = lm_logits(cfg, params, x[:, -1:])
        return logits, cache

    def _prefill_recurrent(self, params, x, positions, cache_len):
        cfg = self.cfg
        if cfg.family == "ssm":
            def body(carry, lp):
                h = apply_norm(cfg, lp["ln"], carry)
                y, st = ssm_mod.mamba_forward(cfg, lp["mamba"], h, return_state=True)
                return carry + y, st

            return jax.lax.scan(body, x, params["blocks"])
        # hybrid: groups of mamba layers + shared attention with its own cache
        k = cfg.attn_every
        groups = cfg.num_layers // k
        stacked = jax.tree.map(
            lambda a: a.reshape((groups, k) + a.shape[1:]), params["blocks"]
        )

        def group_body(carry, gp):
            def layer_body(c, lp):
                h = apply_norm(cfg, lp["ln"], c)
                y, st = ssm_mod.mamba_forward(cfg, lp["mamba"], h, return_state=True)
                return c + y, st

            xx, mcache = jax.lax.scan(layer_body, carry, gp)
            sp = params["shared_attn"]
            h = apply_norm(cfg, sp["ln1"], xx)
            y, kvc = attn.gqa_prefill_with_cache(cfg, sp["attn"], h, positions, cache_len)
            xx = xx + y
            h = apply_norm(cfg, sp["ln2"], xx)
            xx = xx + mlp_apply(sp["mlp"], h, swiglu=True)
            return xx, (mcache, kvc)

        x, (mcache, kvc) = jax.lax.scan(group_body, x, stacked)
        mcache = jax.tree.map(
            lambda a: a.reshape((cfg.num_layers,) + a.shape[2:]), mcache
        )
        return x, {"mamba": mcache, "shared": kvc}

    def _prefill_encdec(self, params, batch, cache_len):
        cfg = self.cfg
        enc_out = _encode(cfg, params, batch["audio_embed"])
        tokens = batch["tokens"]
        x = embed_inputs(cfg, params, tokens)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

        def body(carry, lp):
            h = apply_norm(cfg, lp["ln1"], carry)
            y, c = attn.gqa_prefill_with_cache(cfg, lp["attn"], h, positions, cache_len, use_rope=False)
            carry = carry + y
            h = apply_norm(cfg, lp["ln_x"], carry)
            ek, ev = attn.encoder_kv(cfg, lp["cross"], enc_out)
            carry = carry + attn.cross_attention_forward(cfg, lp["cross"], h, ek, ev)
            h = apply_norm(cfg, lp["ln2"], carry)
            carry = carry + mlp_apply(lp["mlp"], h, swiglu=False)
            return carry, (c, ek, ev)

        x, (c, ek, ev) = jax.lax.scan(body, x, params["blocks"])
        x = apply_norm(cfg, params["ln_f"], x)
        logits = lm_logits(cfg, params, x[:, -1:])
        return logits, {"k": c["k"], "v": c["v"], "cross_k": ek, "cross_v": ev}

    # -- serving: one decode step -------------------------------------------
    def decode_step(self, params, tokens, cache, index):
        """tokens: [b, 1]; index: [] int32 tokens already in the cache."""
        cfg = self.cfg
        x = embed_inputs(cfg, params, tokens, offset=index)
        x = shard_batch(x, None, None)

        if cfg.family == "enc_dec":
            def body(carry, xs):
                lp, c, ek, ev = xs
                h = apply_norm(cfg, lp["ln1"], carry)
                y, cc = attn.gqa_decode_step(cfg, lp["attn"], h, c, index, use_rope=False)
                carry = carry + y
                h = apply_norm(cfg, lp["ln_x"], carry)
                carry = carry + attn.cross_attention_forward(cfg, lp["cross"], h, ek, ev)
                h = apply_norm(cfg, lp["ln2"], carry)
                carry = carry + mlp_apply(lp["mlp"], h, swiglu=False)
                return carry, cc

            kv = {"k": cache["k"], "v": cache["v"]}
            x, new_kv = jax.lax.scan(
                body, x, (params["blocks"], kv, cache["cross_k"], cache["cross_v"])
            )
            new_cache = dict(new_kv, cross_k=cache["cross_k"], cross_v=cache["cross_v"])
        elif cfg.family == "ssm":
            def body(carry, xs):
                lp, c = xs
                h = apply_norm(cfg, lp["ln"], carry)
                y, cc = ssm_mod.mamba_decode_step(cfg, lp["mamba"], h, c)
                return carry + y, cc

            x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        elif cfg.family == "hybrid":
            k = cfg.attn_every
            groups = cfg.num_layers // k
            stacked = jax.tree.map(
                lambda a: a.reshape((groups, k) + a.shape[1:]), params["blocks"]
            )
            mcache = jax.tree.map(
                lambda a: a.reshape((groups, k) + a.shape[1:]), cache["mamba"]
            )

            def group_body(carry, xs):
                gp, mc, sc = xs

                def layer_body(c, l_xs):
                    lp, lc = l_xs
                    h = apply_norm(cfg, lp["ln"], c)
                    y, cc = ssm_mod.mamba_decode_step(cfg, lp["mamba"], h, lc)
                    return c + y, cc

                xx, new_mc = jax.lax.scan(layer_body, carry, (gp, mc))
                sp = params["shared_attn"]
                h = apply_norm(cfg, sp["ln1"], xx)
                y, new_sc = attn.gqa_decode_step(cfg, sp["attn"], h, sc, index)
                xx = xx + y
                h = apply_norm(cfg, sp["ln2"], xx)
                xx = xx + mlp_apply(sp["mlp"], h, swiglu=True)
                return xx, (new_mc, new_sc)

            x, (new_mc, new_sc) = jax.lax.scan(group_body, x, (stacked, mcache, cache["shared"]))
            new_cache = {
                "mamba": jax.tree.map(
                    lambda a: a.reshape((cfg.num_layers,) + a.shape[2:]), new_mc
                ),
                "shared": new_sc,
            }
        else:
            def body(carry, xs):
                lp, c = xs
                h = apply_norm(cfg, lp["ln1"], carry)
                if cfg.use_mla:
                    y, cc = attn.mla_decode_step(cfg, lp["attn"], h, c, index)
                else:
                    y, cc = attn.gqa_decode_step(cfg, lp["attn"], h, c, index)
                carry = carry + y
                h = apply_norm(cfg, lp["ln2"], carry)
                if cfg.num_experts:
                    yy, _ = moe_mod.moe_apply(cfg, lp["moe"], h)
                    carry = carry + yy
                else:
                    carry = carry + mlp_apply(lp["mlp"], h, swiglu=cfg.mlp_swiglu)
                return carry, cc

            x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))

        x = apply_norm(cfg, params["ln_f"], x)
        logits = lm_logits(cfg, params, x)
        return logits, new_cache

    # -- dry-run helpers -----------------------------------------------------
    def cache_abstract(self, batch: int, cache_len: int):
        return cache_abstract(self.cfg, batch, cache_len)

    def cache_specs(self):
        return cache_specs(self.cfg)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
