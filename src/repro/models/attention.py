"""Attention blocks: GQA (+RoPE, optional QKV bias) and MLA (deepseek-v2),
with train/prefill paths and KV-cached decode paths.

TPU sharding layout:
  * query heads sharded over 'model' (configs pad head counts to the TP
    degree where needed — see configs/*.py);
  * KV projection weights replicated (num_kv_heads is usually < TP degree);
  * decode KV caches sharded over the *sequence* dim on 'model'
    (flash-decode style: XLA partitions the softmax/contraction with
    all-reduces of the per-shard partial stats);
  * long prefills use a query-chunked online-softmax attention
    (``chunked_attention``) so the S x S score matrix never materializes —
    the pure-jnp analogue of the Pallas flash kernel in
    ``repro/kernels/flash_attention.py``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDecl, apply_rope, tp_contract
from repro.models.sharding import batch_spec, shard, shard_batch

NEG_INF = -1e30
# materialize full scores only below this many query positions
CHUNKED_ATTENTION_THRESHOLD = 8_192
QUERY_CHUNK = 1_024


# ---------------------------------------------------------------------------
# Declarations
# ---------------------------------------------------------------------------


def gqa_decls(cfg: ModelConfig, heads: int | None = None) -> dict[str, ParamDecl]:
    from repro.models.transformer import padded_kv_heads

    d, hd = cfg.d_model, cfg.resolved_head_dim
    h = heads or cfg.num_heads
    kvh = padded_kv_heads(cfg)
    out = {
        "wq": ParamDecl((d, h, hd), ("embed", "heads", "head"), init="scaled"),
        "wk": ParamDecl((d, kvh, hd), ("embed", "kv_heads", "head"), init="scaled"),
        "wv": ParamDecl((d, kvh, hd), ("embed", "kv_heads", "head"), init="scaled"),
        "wo": ParamDecl((h, hd, d), ("heads", "head", "embed"), init="scaled"),
    }
    if cfg.qkv_bias:
        out["bq"] = ParamDecl((h, hd), ("heads", "head"), init="zeros")
        out["bk"] = ParamDecl((kvh, hd), ("kv_heads", "head"), init="zeros")
        out["bv"] = ParamDecl((kvh, hd), ("kv_heads", "head"), init="zeros")
    return out


def mla_decls(cfg: ModelConfig) -> dict[str, ParamDecl]:
    d, h = cfg.d_model, cfg.num_heads
    nope, rope, vh, lora = (
        cfg.qk_nope_dim,
        cfg.qk_rope_dim,
        cfg.v_head_dim,
        cfg.kv_lora_rank,
    )
    return {
        "wq": ParamDecl((d, h, nope + rope), ("embed", "heads", "head"), init="scaled"),
        "w_dkv": ParamDecl((d, lora), ("embed", "lora"), init="scaled"),
        "w_kr": ParamDecl((d, rope), ("embed", "head"), init="scaled"),
        "kv_norm": ParamDecl((lora,), ("lora",), init="ones", dtype="float32"),
        "w_uk": ParamDecl((lora, h, nope), ("lora", "heads", "head"), init="scaled"),
        "w_uv": ParamDecl((lora, h, vh), ("lora", "heads", "head"), init="scaled"),
        "wo": ParamDecl((h, vh, d), ("heads", "head", "embed"), init="scaled"),
    }


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------


def _repeat_kv(x: jnp.ndarray, n: int) -> jnp.ndarray:
    """[b, s, kvh, d] -> [b, s, kvh*n, d]."""
    if n == 1:
        return x
    return jnp.repeat(x, n, axis=2)


def full_attention(
    q: jnp.ndarray,  # [b, sq, h, d]
    k: jnp.ndarray,  # [b, sk, h, d]
    v: jnp.ndarray,  # [b, sk, h, dv]
    *,
    causal: bool,
    q_offset: int = 0,
    scale: float | None = None,
) -> jnp.ndarray:
    scale = scale or 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = kpos[None, :] <= qpos[:, None]
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    probs = shard_batch(probs, "model", None, None)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool,
    q_offset: int = 0,
    chunk: int = QUERY_CHUNK,
    scale: float | None = None,
) -> jnp.ndarray:
    """Query-chunked online-softmax attention (flash-style, pure jnp).

    Scans over query chunks; per-chunk memory is [b, h, chunk, sk]."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    dv = v.shape[-1]
    scale = scale or 1.0 / math.sqrt(d)
    if sq % chunk != 0:  # fall back (shapes here are powers of two anyway)
        return full_attention(q, k, v, causal=causal, q_offset=q_offset, scale=scale)
    nchunks = sq // chunk
    qc = q.reshape(b, nchunks, chunk, h, d).transpose(1, 0, 2, 3, 4)

    kpos = jnp.arange(sk)

    def body(carry, args):
        i, qblk = args  # qblk: [b, chunk, h, d]
        scores = jnp.einsum("bqhd,bkhd->bhqk", qblk, k).astype(jnp.float32) * scale
        if causal:
            qpos = i * chunk + jnp.arange(chunk) + q_offset
            mask = kpos[None, :] <= qpos[:, None]
            scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
        return carry, out

    _, outs = jax.lax.scan(body, None, (jnp.arange(nchunks), qc))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dv)


def _attend(q, k, v, *, causal, q_offset=0, scale=None):
    if q.shape[1] > CHUNKED_ATTENTION_THRESHOLD:
        return chunked_attention(q, k, v, causal=causal, q_offset=q_offset, scale=scale)
    return full_attention(q, k, v, causal=causal, q_offset=q_offset, scale=scale)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------


def _project_qkv(cfg: ModelConfig, params, x):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    return q, k, v


def gqa_forward(
    cfg: ModelConfig,
    params,
    x: jnp.ndarray,  # [b, s, d]
    positions: jnp.ndarray,  # [b, s]
    *,
    causal: bool = True,
    use_rope: bool = True,
) -> jnp.ndarray:
    """Training / prefill attention over a full sequence."""
    q, k, v = _project_qkv(cfg, params, x)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard_batch(q, None, "model", None)
    groups = q.shape[2] // k.shape[2]
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    out = _attend(q, k, v, causal=causal)
    out = shard_batch(out, None, "model", None)
    return tp_contract("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def gqa_prefill_with_cache(
    cfg: ModelConfig, params, x, positions, cache_len: int, *, use_rope: bool = True
) -> tuple[jnp.ndarray, dict[str, jnp.ndarray]]:
    """Prefill that also returns a KV cache padded to ``cache_len``,
    sequence-sharded over 'model' for the decode phase."""
    q, k, v = _project_qkv(cfg, params, x)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    s = x.shape[1]
    pad = cache_len - s
    k_cache = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    v_cache = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    k_cache = shard_batch(k_cache, "model", None, None)
    v_cache = shard_batch(v_cache, "model", None, None)
    groups = q.shape[2] // k.shape[2]
    out = _attend(q, _repeat_kv(k, groups), _repeat_kv(v, groups), causal=True)
    out = shard_batch(out, None, "model", None)
    y = tp_contract("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, {"k": k_cache, "v": v_cache}


def gqa_decode_step(
    cfg: ModelConfig,
    params,
    x: jnp.ndarray,  # [b, 1, d]
    cache: dict[str, jnp.ndarray],  # k/v: [b, S, kvh, hd], seq-sharded
    index: jnp.ndarray,  # [] int32: number of tokens already in cache
    use_rope: bool = True,
) -> tuple[jnp.ndarray, dict[str, jnp.ndarray]]:
    q, k_new, v_new = _project_qkv(cfg, params, x)
    if use_rope:
        pos = jnp.full((x.shape[0], 1), index, dtype=jnp.int32)
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, index, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, index, 0, 0))
    k = shard_batch(k, "model", None, None)
    v = shard_batch(v, "model", None, None)
    groups = q.shape[2] // k.shape[2]
    kk = _repeat_kv(k, groups)
    vv = _repeat_kv(v, groups)
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk).astype(jnp.float32) * scale
    valid = jnp.arange(k.shape[1])[None, None, None, :] <= index
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
    y = tp_contract("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA (deepseek-v2): low-rank compressed KV
# ---------------------------------------------------------------------------


def _mla_q(cfg, params, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_dim :], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(cfg, params, x, positions):
    c_kv = jnp.einsum("bsd,dl->bsl", x, params["w_dkv"].astype(x.dtype))
    # RMSNorm on the compressed kv stream (deepseek-v2)
    c32 = c_kv.astype(jnp.float32)
    c32 = c32 * jax.lax.rsqrt(jnp.mean(jnp.square(c32), -1, keepdims=True) + cfg.norm_eps)
    c_kv = (c32 * params["kv_norm"]).astype(x.dtype)
    k_rope = jnp.einsum("bsd,dk->bsk", x, params["w_kr"].astype(x.dtype))
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_forward(cfg: ModelConfig, params, x, positions, *, causal: bool = True):
    """Expanded-form MLA for training/prefill."""
    q_nope, q_rope = _mla_q(cfg, params, x, positions)
    c_kv, k_rope = _mla_ckv(cfg, params, x, positions)
    k_nope = jnp.einsum("bsl,lhn->bshn", c_kv, params["w_uk"].astype(x.dtype))
    v = jnp.einsum("bsl,lhn->bshn", c_kv, params["w_uv"].astype(x.dtype))
    h = k_nope.shape[2]
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], k_rope.shape[:2] + (h, k_rope.shape[-1]))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    q = shard_batch(q, None, "model", None)
    k = shard_batch(k, None, "model", None)
    out = _attend(q, k, v, causal=causal, scale=1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim))
    out = shard_batch(out, None, "model", None)
    return tp_contract("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def mla_prefill_with_cache(cfg, params, x, positions, cache_len: int):
    y = mla_forward(cfg, params, x, positions, causal=True)
    c_kv, k_rope = _mla_ckv(cfg, params, x, positions)
    pad = cache_len - x.shape[1]
    cache = {
        "c_kv": shard_batch(jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))), "model", None),
        "k_rope": shard_batch(jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))), "model", None),
    }
    return y, cache


def mla_decode_step(cfg: ModelConfig, params, x, cache, index):
    """Absorbed-matmul MLA decode: attention runs in the compressed space —
    the cache holds only c_kv [b, S, lora] and k_rope [b, S, rope]."""
    b = x.shape[0]
    pos = jnp.full((b, 1), index, dtype=jnp.int32)
    q_nope, q_rope = _mla_q(cfg, params, x, pos)
    c_new, kr_new = _mla_ckv(cfg, params, x, pos)
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, index, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, index, 0))
    c_kv = shard_batch(c_kv, "model", None)
    k_rope = shard_batch(k_rope, "model", None)
    # absorb W_uk into the query:  q~ = W_uk^T q_nope   [b, 1, h, lora]
    q_t = jnp.einsum("bqhn,lhn->bqhl", q_nope, params["w_uk"].astype(x.dtype))
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    scores = (
        jnp.einsum("bqhl,bsl->bhqs", q_t, c_kv)
        + jnp.einsum("bqhr,bsr->bhqs", q_rope, k_rope)
    ).astype(jnp.float32) * scale
    valid = jnp.arange(c_kv.shape[1])[None, None, None, :] <= index
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqs,bsl->bqhl", probs, c_kv)  # attend in compressed space
    out = jnp.einsum("bqhl,lhn->bqhn", ctx, params["w_uv"].astype(x.dtype))
    y = tp_contract("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, {"c_kv": c_kv, "k_rope": k_rope}


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------


def cross_attention_forward(
    cfg: ModelConfig, params, x, enc_k, enc_v
) -> jnp.ndarray:
    """Decoder cross-attention against precomputed encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
    groups = q.shape[2] // enc_k.shape[2]
    out = _attend(q, _repeat_kv(enc_k, groups), _repeat_kv(enc_v, groups), causal=False)
    return tp_contract("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def encoder_kv(cfg: ModelConfig, params, enc_out) -> tuple[jnp.ndarray, jnp.ndarray]:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(enc_out.dtype))
    if cfg.qkv_bias:
        k = k + params["bk"].astype(enc_out.dtype)
        v = v + params["bv"].astype(enc_out.dtype)
    return k, v
