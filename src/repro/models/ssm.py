"""Mamba2 (SSD — state-space duality) sequence mixing.

Chunked SSD algorithm (Dao & Gu 2024): the sequence is split into chunks of
length L; within a chunk the recurrence is computed as a masked, decay-
weighted attention-like quadratic form; chunk-final states are carried by a
(sequential) scan and injected into the next chunk.  Decode maintains the
recurrent state [b, h, p, n] plus a small causal-conv tail — O(1) per token,
which is why the SSM/hybrid archs run the ``long_500k`` cell.

Sharding: heads ('ssm_heads' / 'd_inner') over 'model'; the B/C streams
(n_groups = 1) are replicated — they are tiny.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDecl, tp_contract
from repro.models.sharding import shard_batch

N_GROUPS = 1


def ssm_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_head_dim
    return d_inner, heads, cfg.ssm_state


def mamba_decls(cfg: ModelConfig) -> dict[str, ParamDecl]:
    d = cfg.d_model
    d_inner, h, n = ssm_dims(cfg)
    gn = N_GROUPS * n
    conv = cfg.ssm_conv
    return {
        "wz": ParamDecl((d, d_inner), ("embed", "d_inner"), init="scaled"),
        "wx": ParamDecl((d, d_inner), ("embed", "d_inner"), init="scaled"),
        "wB": ParamDecl((d, gn), ("embed", "state"), init="scaled"),
        "wC": ParamDecl((d, gn), ("embed", "state"), init="scaled"),
        "w_dt": ParamDecl((d, h), ("embed", "ssm_heads"), init="scaled"),
        "dt_bias": ParamDecl((h,), ("ssm_heads",), init="zeros", dtype="float32"),
        "A_log": ParamDecl((h,), ("ssm_heads",), init="zeros", dtype="float32"),
        "D": ParamDecl((h,), ("ssm_heads",), init="ones", dtype="float32"),
        "conv_x": ParamDecl((conv, d_inner), ("conv", "d_inner"), init="scaled"),
        "conv_B": ParamDecl((conv, gn), ("conv", "state"), init="scaled"),
        "conv_C": ParamDecl((conv, gn), ("conv", "state"), init="scaled"),
        "norm": ParamDecl((d_inner,), ("d_inner",), init="ones", dtype="float32"),
        "out_proj": ParamDecl((d_inner, d), ("d_inner", "embed"), init="scaled"),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, tail: jnp.ndarray | None = None):
    """Depthwise causal conv over [b, s, ch] with kernel [k, ch].
    ``tail`` [b, k-1, ch] prepends state from previous tokens (decode)."""
    k = w.shape[0]
    if tail is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype)


def _ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD over a full sequence.

    x: [b, s, h, p]; dt: [b, s, h] (post-softplus); A: [h] (negative);
    B, C: [b, s, n] (single group).  Returns (y [b,s,h,p], state [b,h,p,n])."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    L = min(chunk, s)
    if s % L:
        pad = L - s % L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    sp = x.shape[1]
    c = sp // L
    xc = x.reshape(b, c, L, h, p)
    dtc = dt.reshape(b, c, L, h).astype(jnp.float32)
    Bc = B.reshape(b, c, L, n)
    Cc = C.reshape(b, c, L, n)

    dA = dtc * A  # [b,c,L,h], negative
    dA_cs = jnp.cumsum(dA, axis=2)  # inclusive cumsum within chunk
    seg_sum = dA_cs[:, :, -1:, :]  # [b,c,1,h]

    # intra-chunk: y[i] += sum_{j<=i} C_i.B_j exp(dAcs_i - dAcs_j) dt_j x_j
    decay = jnp.exp(dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :])  # [b,c,i,j,h]
    mask = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(mask[None, None, :, :, None], decay, 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    attn = cb[..., None] * decay  # [b,c,i,j,h]
    dtx = dtc[..., None] * xc.astype(jnp.float32)  # [b,c,L,h,p]
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", attn, dtx)

    # chunk-final states: S_c = sum_j B_j exp(seg - dAcs_j) dt_j x_j
    state_decay = jnp.exp(seg_sum - dA_cs)  # [b,c,L,h]
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc.astype(jnp.float32),
                        state_decay * dtc, xc.astype(jnp.float32))

    # inter-chunk recurrence: h_c = exp(seg_c) h_{c-1} + S_c
    seg = jnp.exp(seg_sum[:, :, 0, :])  # [b,c,h]

    def scan_fn(carry, inp):
        s_c, g_c = inp  # [b,h,p,n], [b,h]
        new = carry * g_c[..., None, None] + s_c
        return new, carry  # emit state *entering* the chunk

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, init, (states.transpose(1, 0, 2, 3, 4), seg.transpose(1, 0, 2))
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [b,c,h,p,n]

    # inter-chunk contribution: y[i] += C_i exp(dAcs_i) h_prev
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc.astype(jnp.float32),
                       prev_states, jnp.exp(dA_cs))
    y = (y_diag + y_off).reshape(b, sp, h, p)[:, :s]
    return y, final


def mamba_forward(
    cfg: ModelConfig, params, x: jnp.ndarray, *, return_state: bool = False
):
    """Full-sequence Mamba2 block (train / prefill).  x: [b, s, d]."""
    dt_raw = jnp.einsum("bsd,dh->bsh", x, params["w_dt"].astype(x.dtype))
    z = jnp.einsum("bsd,de->bse", x, params["wz"].astype(x.dtype))
    xin = jnp.einsum("bsd,de->bse", x, params["wx"].astype(x.dtype))
    Braw = jnp.einsum("bsd,dn->bsn", x, params["wB"].astype(x.dtype))
    Craw = jnp.einsum("bsd,dn->bsn", x, params["wC"].astype(x.dtype))

    xc = _causal_conv(xin, params["conv_x"].astype(x.dtype))
    Bc = _causal_conv(Braw, params["conv_B"].astype(x.dtype))
    Cc = _causal_conv(Craw, params["conv_C"].astype(x.dtype))

    d_inner, h, n = ssm_dims(cfg)
    p = cfg.ssm_head_dim
    xh = xc.reshape(*xc.shape[:2], h, p)
    xh = shard_batch(xh, None, "model", None)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])  # [h]

    y, state = _ssd_chunked(xh, dt, A, Bc, Cc, cfg.ssm_chunk)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*y.shape[:2], d_inner)

    # gated RMSNorm
    g = y * jax.nn.silu(z.astype(jnp.float32))
    g = g * jax.lax.rsqrt(jnp.mean(jnp.square(g), -1, keepdims=True) + cfg.norm_eps)
    g = (g * params["norm"]).astype(x.dtype)
    out = tp_contract("bse,ed->bsd", g, params["out_proj"].astype(x.dtype))
    if return_state:
        conv_tail = {
            "x": xin[:, -(cfg.ssm_conv - 1):, :],
            "B": Braw[:, -(cfg.ssm_conv - 1):, :],
            "C": Craw[:, -(cfg.ssm_conv - 1):, :],
        }
        return out, {"state": state, "conv": conv_tail}
    return out


def mamba_decode_step(cfg: ModelConfig, params, x: jnp.ndarray, cache):
    """Single-token recurrent step.  x: [b, 1, d]; cache from prefill."""
    d_inner, h, n = ssm_dims(cfg)
    p = cfg.ssm_head_dim
    dt_raw = jnp.einsum("bsd,dh->bsh", x, params["w_dt"].astype(x.dtype))
    z = jnp.einsum("bsd,de->bse", x, params["wz"].astype(x.dtype))
    xin = jnp.einsum("bsd,de->bse", x, params["wx"].astype(x.dtype))
    Braw = jnp.einsum("bsd,dn->bsn", x, params["wB"].astype(x.dtype))
    Craw = jnp.einsum("bsd,dn->bsn", x, params["wC"].astype(x.dtype))

    conv = cache["conv"]
    xc = _causal_conv(xin, params["conv_x"].astype(x.dtype), tail=conv["x"])
    Bc = _causal_conv(Braw, params["conv_B"].astype(x.dtype), tail=conv["B"])
    Cc = _causal_conv(Craw, params["conv_C"].astype(x.dtype), tail=conv["C"])
    new_conv = {
        "x": jnp.concatenate([conv["x"].astype(x.dtype), xin], 1)[:, 1:],
        "B": jnp.concatenate([conv["B"].astype(x.dtype), Braw], 1)[:, 1:],
        "C": jnp.concatenate([conv["C"].astype(x.dtype), Craw], 1)[:, 1:],
    }

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])  # [b,h]
    A = -jnp.exp(params["A_log"])
    g_decay = jnp.exp(dt * A)  # [b,h]
    xh = xc[:, 0].reshape(-1, h, p).astype(jnp.float32)  # [b,h,p]
    Bv = Bc[:, 0].astype(jnp.float32)  # [b,n]
    Cv = Cc[:, 0].astype(jnp.float32)
    state = cache["state"]  # [b,h,p,n]
    state = state * g_decay[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, Bv
    )
    y = jnp.einsum("bhpn,bn->bhp", state, Cv) + params["D"][None, :, None] * xh
    y = y.reshape(-1, 1, d_inner)
    g = y * jax.nn.silu(z.astype(jnp.float32))
    g = g * jax.lax.rsqrt(jnp.mean(jnp.square(g), -1, keepdims=True) + cfg.norm_eps)
    g = (g * params["norm"]).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", g, params["out_proj"].astype(x.dtype))
    return out, {"state": state, "conv": new_conv}


def mamba_reference_recurrent(cfg: ModelConfig, params, x: jnp.ndarray):
    """Token-by-token recurrence oracle (tests): must match mamba_forward."""
    b, s, d = x.shape
    d_inner, h, n = ssm_dims(cfg)
    p = cfg.ssm_head_dim
    cache = {
        "state": jnp.zeros((b, h, p, n), jnp.float32),
        "conv": {
            "x": jnp.zeros((b, cfg.ssm_conv - 1, d_inner), x.dtype),
            "B": jnp.zeros((b, cfg.ssm_conv - 1, N_GROUPS * n), x.dtype),
            "C": jnp.zeros((b, cfg.ssm_conv - 1, N_GROUPS * n), x.dtype),
        },
    }
    outs = []
    for i in range(s):
        y, cache = mamba_decode_step(cfg, params, x[:, i : i + 1], cache)
        outs.append(y)
    return jnp.concatenate(outs, axis=1), cache
