"""Mixture-of-experts with chunk-local sort-based capacity dispatch.

Tokens choose top-k experts.  Dispatch is *chunk-local*: the token stream is
split into ``cfg.moe_dispatch_chunks`` chunks (set equal to the DP degree in
production) and tokens compete for per-expert capacity only within their
chunk.  All data-dependent gathers/scatters then carry a leading chunk dim
that is sharded over 'data' — they never move data across shards, so the SPMD
partitioner keeps every dispatch op local instead of replicating token
buffers (the classic pjit-MoE memory blowup).

Cross-device communication reduces to:
  * deepseek-mode (E % tp == 0): expert dim sharded over 'model'; the combine
    all-gathers y_grouped over 'model' (the EP combine collective);
  * grok-mode (E < tp):每 expert's ffn dim sharded over 'model'; the down-proj
    contraction psums over 'model'.

The [x, E, C, d] grouped buffer and [x, E, C, f] hidden are explicitly
annotated so the partitioner cannot fall back to replication.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDecl, round_up, tp_contract
from repro.models.sharding import shard


def moe_decls(cfg: ModelConfig) -> dict[str, ParamDecl]:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.d_ff_expert
    out = {
        "router": ParamDecl((d, e), ("embed", "none"), init="scaled"),
        "w_gate": ParamDecl((e, d, f), ("expert", "embed2", "expert_mlp"), init="scaled"),
        "w_up": ParamDecl((e, d, f), ("expert", "embed2", "expert_mlp"), init="scaled"),
        "w_down": ParamDecl((e, f, d), ("expert", "expert_mlp", "embed2"), init="scaled"),
    }
    if cfg.num_shared_experts:
        fs = cfg.num_shared_experts * f
        out["shared_gate"] = ParamDecl((d, fs), ("embed", "mlp"), init="scaled")
        out["shared_up"] = ParamDecl((d, fs), ("embed", "mlp"), init="scaled")
        out["shared_down"] = ParamDecl((fs, d), ("mlp", "embed"), init="scaled")
    return out


def _ep_mode(cfg: ModelConfig) -> bool:
    """True -> expert dim sharded over 'model' (deepseek); False -> per-
    expert ffn dim sharded (grok)."""
    return cfg.num_experts % 16 == 0


# ---------------------------------------------------------------------------
# Gather-only dispatch/combine.
#
# The naive `.at[dest].set(tokens[src])` formulation is correct but its VJP
# is a scatter whose SPMD partitioning materializes full-width u32 one-hot
# buffers (observed: 18 TiB/device on deepseek train_4k).  With precomputed
# index tables both directions of both ops are plain batched gathers:
#
#   dispatch fwd : grouped[slot] = tokens[src_of_slot]
#   dispatch bwd : d_tokens[t]   = Σ_j d_grouped[slot_of_pair[t,j]]
#   combine  fwd : out[t]        = Σ_j gate[t,j] · y[slot_of_pair[t,j]]
#   combine  bwd : d_y[slot]     = gate_of_slot · d_out[src_of_slot]
#                  d_gate[t,j]   = y[slot_of_pair[t,j]] · d_out[t]
#
# Pad rows (token index t, slot index n_slots) absorb drops/unused slots.
# ---------------------------------------------------------------------------


def _take(arr, idx):
    """Batched row gather: arr [x, n, d], idx [x, m] -> [x, m, d]."""
    return jnp.take_along_axis(arr, idx[..., None], axis=1)


@jax.custom_vjp
def _dispatch(tokens, src_of_slot, slot_of_pair):
    tok_pad = jnp.concatenate([tokens, jnp.zeros_like(tokens[:, :1])], axis=1)
    return _take(tok_pad, src_of_slot)


def _dispatch_fwd(tokens, src_of_slot, slot_of_pair):
    return _dispatch(tokens, src_of_slot, slot_of_pair), (
        slot_of_pair,
        tokens.shape,
    )


def _dispatch_bwd(res, d_grouped):
    slot_of_pair, tok_shape = res
    nx, t, d = tok_shape
    k = slot_of_pair.shape[1] // t
    dg_pad = jnp.concatenate([d_grouped, jnp.zeros_like(d_grouped[:, :1])], axis=1)
    d_pairs = _take(dg_pad, slot_of_pair)  # [x, t*k, d]
    d_tokens = d_pairs.reshape(nx, t, k, d).sum(axis=2)
    return d_tokens, None, None


_dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


@jax.custom_vjp
def _combine(y_flat, gates, slot_of_pair, src_of_slot, pair_of_slot):
    nx, t, k = gates.shape
    d = y_flat.shape[-1]
    y_pad = jnp.concatenate([y_flat, jnp.zeros_like(y_flat[:, :1])], axis=1)
    y_pairs = _take(y_pad, slot_of_pair).reshape(nx, t, k, d)
    return (y_pairs * gates[..., None]).sum(axis=2)


def _combine_fwd(y_flat, gates, slot_of_pair, src_of_slot, pair_of_slot):
    out = _combine(y_flat, gates, slot_of_pair, src_of_slot, pair_of_slot)
    return out, (y_flat, gates, slot_of_pair, src_of_slot, pair_of_slot)


def _combine_bwd(res, d_out):
    y_flat, gates, slot_of_pair, src_of_slot, pair_of_slot = res
    nx, t, k = gates.shape
    d = y_flat.shape[-1]
    gates_flat = gates.reshape(nx, t * k)
    gf_pad = jnp.concatenate(
        [gates_flat, jnp.zeros_like(gates_flat[:, :1])], axis=1
    )
    gate_of_slot = jnp.take_along_axis(gf_pad, jnp.minimum(pair_of_slot, t * k), axis=1)
    do_pad = jnp.concatenate([d_out, jnp.zeros_like(d_out[:, :1])], axis=1)
    d_y = _take(do_pad, src_of_slot) * gate_of_slot[..., None]  # [x, slots, d]
    y_pad = jnp.concatenate([y_flat, jnp.zeros_like(y_flat[:, :1])], axis=1)
    y_pairs = _take(y_pad, slot_of_pair).reshape(nx, t, k, d)
    d_gates = (y_pairs * d_out[:, :, None, :]).sum(axis=-1)
    return d_y, d_gates.astype(gates.dtype), None, None, None


_combine.defvjp(_combine_fwd, _combine_bwd)


def moe_apply(
    cfg: ModelConfig,
    params,
    x: jnp.ndarray,  # [b, s, d]
    *,
    capacity_factor: float | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output [b,s,d], aux load-balance loss [])."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cf = capacity_factor or cfg.capacity_factor
    nx = cfg.moe_dispatch_chunks if b % max(cfg.moe_dispatch_chunks, 1) == 0 else 1
    t = (b // nx) * s  # tokens per chunk
    tokens = x.reshape(nx, t, d)
    tokens = shard(tokens, P("data", None, None))

    logits = jnp.einsum("xtd,de->xte", tokens, params["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # [x, t, k]
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux loss (Switch-style), computed over the full stream
    me = probs.mean(axis=(0, 1))  # [e]
    ce = (
        jnp.zeros((e,), jnp.float32).at[gate_idx.reshape(-1)].add(1.0)
        / (nx * t * k)
    )
    aux = (me * ce).sum() * e

    # ---- chunk-local sort of (token, expert) pairs ----
    flat_expert = gate_idx.reshape(nx, t * k)
    sort_idx = jnp.argsort(flat_expert, axis=-1)  # [x, tk]
    sorted_expert = jnp.take_along_axis(flat_expert, sort_idx, axis=-1)
    counts = jnp.zeros((nx, e), jnp.int32).at[
        jnp.arange(nx)[:, None], flat_expert
    ].add(1)
    seg_start = jnp.cumsum(counts, axis=-1) - counts  # [x, e]
    pos_in_expert = (
        jnp.arange(t * k, dtype=jnp.int32)[None]
        - jnp.take_along_axis(seg_start, sorted_expert, axis=-1)
    )

    capacity = round_up(max(int(math.ceil(t * k * cf / e)), 8), 8)
    keep = pos_in_expert < capacity  # [x, tk]
    dest = jnp.where(keep, sorted_expert * capacity + pos_in_expert, e * capacity)
    src_token = sort_idx // k  # [x, tk]

    # ---- index tables (int32, non-differentiable, cheap scatters) ----
    # slot_of_pair[f]: slot each flat (token,expert) pair landed in (pad slot
    # e*capacity when dropped); src_of_slot[slot]: source token (pad token t
    # when the slot is unused); pair_of_slot[slot]: flat pair index.
    n_slots = e * capacity
    slot_of_pair_sorted = jnp.where(keep, dest, n_slots)  # [x, tk]
    xi = jnp.arange(nx)[:, None]
    slot_of_pair = (
        jnp.full((nx, t * k), n_slots, jnp.int32).at[xi, sort_idx].set(slot_of_pair_sorted)
    )
    src_of_slot = (
        jnp.full((nx, n_slots + 1), t, jnp.int32)
        .at[xi, jnp.minimum(slot_of_pair_sorted, n_slots)]
        .set(jnp.where(keep, src_token, t))
    )[:, :n_slots]
    pair_of_slot = (
        jnp.full((nx, n_slots + 1), t * k, jnp.int32)
        .at[xi, jnp.minimum(slot_of_pair_sorted, n_slots)]
        .set(jnp.where(keep, sort_idx, t * k))
    )[:, :n_slots]

    # ---- gather-only dispatch (backward is a gather too — custom_vjp) ----
    grouped = _dispatch(tokens, src_of_slot, slot_of_pair)
    grouped = grouped.reshape(nx, e, capacity, d)
    grouped = shard(grouped, P("data", None, None, None))

    # ---- grouped expert FFN (swiglu) ----
    wg = params["w_gate"].astype(x.dtype)
    wu = params["w_up"].astype(x.dtype)
    wd = params["w_down"].astype(x.dtype)
    hspec = (
        P("data", "model", None, None) if _ep_mode(cfg) else P("data", None, None, "model")
    )
    g = shard(jnp.einsum("xecd,edf->xecf", grouped, wg), hspec)
    u = shard(jnp.einsum("xecd,edf->xecf", grouped, wu), hspec)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y_grouped = tp_contract("xecf,efd->xecd", h, wd)
    # combine collective: EP all-gather (deepseek) / ffn psum (grok)
    y_grouped = shard(y_grouped, P("data", None, None, None))

    # ---- gather-only combine ----
    y_flat = y_grouped.reshape(nx, e * capacity, d)
    out = _combine(
        y_flat, gate_vals.astype(x.dtype), slot_of_pair, src_of_slot, pair_of_slot
    )
    out = shard(out, P("data", None, None))

    if cfg.num_shared_experts:
        sg = jnp.einsum("xtd,df->xtf", tokens, params["shared_gate"].astype(x.dtype))
        su = jnp.einsum("xtd,df->xtf", tokens, params["shared_up"].astype(x.dtype))
        sh = jax.nn.silu(sg.astype(jnp.float32)).astype(x.dtype) * su
        out = out + jnp.einsum("xtf,fd->xtd", sh, params["shared_down"].astype(x.dtype))

    return out.reshape(b, s, d), aux


def moe_reference(cfg: ModelConfig, params, x: jnp.ndarray) -> jnp.ndarray:
    """Dense per-token loop-over-experts oracle (tests only, no capacity)."""
    b, s, d = x.shape
    tokens = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", tokens, params["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    out = jnp.zeros_like(tokens)
    for ei in range(cfg.num_experts):
        gi = jnp.einsum("td,df->tf", tokens, params["w_gate"][ei].astype(x.dtype))
        ui = jnp.einsum("td,df->tf", tokens, params["w_up"][ei].astype(x.dtype))
        hi = jax.nn.silu(gi.astype(jnp.float32)).astype(x.dtype) * ui
        yi = jnp.einsum("tf,fd->td", hi, params["w_down"][ei].astype(x.dtype))
        wmatch = jnp.where(gate_idx == ei, gate_vals, 0.0).sum(-1)  # [t]
        out = out + yi * wmatch[:, None].astype(x.dtype)
    if cfg.num_shared_experts:
        sg = jnp.einsum("td,df->tf", tokens, params["shared_gate"].astype(x.dtype))
        su = jnp.einsum("td,df->tf", tokens, params["shared_up"].astype(x.dtype))
        sh = jax.nn.silu(sg.astype(jnp.float32)).astype(x.dtype) * su
        out = out + jnp.einsum("tf,fd->td", sh, params["shared_down"].astype(x.dtype))
    return out.reshape(b, s, d)
