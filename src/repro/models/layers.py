"""Common layers + the parameter-declaration system.

Every parameter is declared once (shape, per-dim logical axes, init), and
generic walkers derive from the declarations:

  * materialized parameters          (``init_from_decls``)
  * ShapeDtypeStruct abstract params (``abstract_from_decls`` — dry-run)
  * PartitionSpec trees              (``specs_from_decls`` via logical->mesh
                                      rules; TP over 'model', optional FSDP
                                      over 'data')

Logical axis names: vocab, embed, heads, kv_heads, head, mlp, expert,
expert_mlp, lora, d_inner, ssm_heads, state, groups, conv, layers, pos, none.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# TP-reduction dtype: projections that contract a 'model'-sharded dim emit
# partial sums that XLA all-reduces in the dot's output dtype.  bf16 halves
# that wire volume (per-shard MXU accumulation stays fp32 internally).
# ---------------------------------------------------------------------------

_TP_REDUCE_DTYPE = None  # None -> XLA default (fp32 accum type)


def set_tp_reduce_dtype(dtype) -> None:
    global _TP_REDUCE_DTYPE
    _TP_REDUCE_DTYPE = dtype


def tp_contract(subscript: str, x, w):
    """einsum whose contraction dim is TP-sharded (the psum site)."""
    if _TP_REDUCE_DTYPE is not None:
        return jnp.einsum(subscript, x, w, preferred_element_type=_TP_REDUCE_DTYPE)
    return jnp.einsum(subscript, x, w)


# ---------------------------------------------------------------------------
# Parameter declarations
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: tuple[int, ...]
    logical: tuple[str, ...]  # one logical axis name per dim
    init: str = "normal"  # normal | zeros | ones | scaled (1/sqrt(fan_in))
    dtype: str | None = None  # override model dtype (e.g. fp32 for norms)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _init_leaf(decl: ParamDecl, key, dtype) -> jnp.ndarray:
    dt = jnp.dtype(decl.dtype or dtype)
    if decl.init == "zeros":
        return jnp.zeros(decl.shape, dt)
    if decl.init == "ones":
        return jnp.ones(decl.shape, dt)
    if decl.init == "scaled":
        fan_in = decl.shape[0] if len(decl.shape) > 1 else decl.shape[0]
        std = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, decl.shape, jnp.float32) * std).astype(dt)
    if decl.init == "normal":
        return (jax.random.normal(key, decl.shape, jnp.float32) * 0.02).astype(dt)
    raise ValueError(decl.init)


def is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def init_from_decls(decls, key, dtype) -> Any:
    leaves, treedef = jax.tree.flatten(decls, is_leaf=is_decl)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_leaf(d, k, dtype) for d, k in zip(leaves, keys)]
    )


def abstract_from_decls(decls, dtype) -> Any:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype or dtype)),
        decls,
        is_leaf=is_decl,
    )


def make_rules(cfg: ModelConfig, fsdp: bool) -> dict[str, str | None]:
    """Logical-axis -> mesh-axis mapping.  TP over 'model'; FSDP adds 'data'
    on the embed axis.  MoE: shard the expert dim when it divides the TP
    degree (deepseek 160/16), else shard each expert's ffn dim (grok 8e)."""
    rules: dict[str, str | None] = {
        "vocab": "model",
        "heads": "model",
        # kv weights replicated unless the (padded) kv head count is TP-
        # divisible (MHA models like qwen1.5-32b shard kv over 'model')
        "kv_heads": "model"
        if (cfg.num_kv_heads + cfg.kv_pad_to - 1) // cfg.kv_pad_to * cfg.kv_pad_to % 16 == 0
        else None,
        "head": None,
        "mlp": "model",
        "lora": None,
        "d_inner": "model",
        "ssm_heads": "model",
        "state": None,
        "groups": None,
        "conv": None,
        "layers": None,
        "pos": None,
        "none": None,
        "embed": "data" if fsdp else None,
        "embed2": "data" if fsdp else None,
        "expert": "model",
        "expert_mlp": None,
    }
    if cfg.num_experts and cfg.num_experts % 16 != 0:
        rules["expert"] = None
        rules["expert_mlp"] = "model"
    return rules


def specs_from_decls(decls, rules: dict[str, str | None]) -> Any:
    def to_spec(d: ParamDecl) -> P:
        return P(*[rules.get(ax) for ax in d.logical])

    return jax.tree.map(to_spec, decls, is_leaf=is_decl)


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------


def rmsnorm_decls(dim: int, axis: str = "embed2") -> dict[str, ParamDecl]:
    return {"scale": ParamDecl((dim,), (axis,), init="ones", dtype="float32")}


def rmsnorm(params, x, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"]).astype(dt)


def layernorm_decls(dim: int, axis: str = "embed2") -> dict[str, ParamDecl]:
    return {
        "scale": ParamDecl((dim,), (axis,), init="ones", dtype="float32"),
        "bias": ParamDecl((dim,), (axis,), init="zeros", dtype="float32"),
    }


def layernorm(params, x, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    return (x * params["scale"] + params["bias"]).astype(dt)


def norm_decls(cfg: ModelConfig, dim: int | None = None) -> dict[str, ParamDecl]:
    dim = dim or cfg.d_model
    if cfg.family == "enc_dec":
        return layernorm_decls(dim)
    return rmsnorm_decls(dim)


def apply_norm(cfg: ModelConfig, params, x) -> jnp.ndarray:
    if cfg.family == "enc_dec":
        return layernorm(params, x, cfg.norm_eps)
    return rmsnorm(params, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., s, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]  # [..., s, 1, hd/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense FFN)
# ---------------------------------------------------------------------------


def mlp_decls(cfg: ModelConfig, d_ff: int | None = None, swiglu: bool = True):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if swiglu:
        return {
            "w_gate": ParamDecl((d, f), ("embed", "mlp"), init="scaled"),
            "w_up": ParamDecl((d, f), ("embed", "mlp"), init="scaled"),
            "w_down": ParamDecl((f, d), ("mlp", "embed"), init="scaled"),
        }
    return {
        "w_up": ParamDecl((d, f), ("embed", "mlp"), init="scaled"),
        "b_up": ParamDecl((f,), ("mlp",), init="zeros"),
        "w_down": ParamDecl((f, d), ("mlp", "embed"), init="scaled"),
        "b_down": ParamDecl((d,), ("embed",), init="zeros"),
    }


def mlp_apply(params, x, swiglu: bool = True) -> jnp.ndarray:
    if swiglu:
        gate = jnp.einsum("...d,df->...f", x, params["w_gate"])
        up = jnp.einsum("...d,df->...f", x, params["w_up"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
        return tp_contract("...f,fd->...d", h, params["w_down"])
    h = jnp.einsum("...d,df->...f", x, params["w_up"]) + params["b_up"].astype(x.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return tp_contract("...f,fd->...d", h, params["w_down"]) + params[
        "b_down"
    ].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def embed_decls(cfg: ModelConfig) -> dict[str, ParamDecl]:
    v = round_up(cfg.vocab_size, 256)  # pad for clean vocab sharding
    out = {"tok": ParamDecl((v, cfg.d_model), ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        out["unembed"] = ParamDecl((cfg.d_model, v), ("embed", "vocab"), init="scaled")
    return out


def embed_lookup(params, tokens, d_model: int, dtype) -> jnp.ndarray:
    return params["tok"].astype(dtype)[tokens]


def unembed(cfg: ModelConfig, params, x) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", x, params["tok"].astype(x.dtype))
    return jnp.einsum("...d,dv->...v", x, params["unembed"].astype(x.dtype))
