"""Model zoo: the 10 assigned architectures behind one API."""

from repro.models.model import Model, build_model, cache_abstract, cache_specs

__all__ = ["Model", "build_model", "cache_abstract", "cache_specs"]
