"""Activation-sharding helpers.

A process-global mesh is installed by the trainer / dry-run / server; layer
code calls :func:`shard` to constrain intermediate activations.  With no mesh
installed (single-device smoke tests) the constraints are no-ops, so the same
model code runs everywhere.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Mesh | None = None


def set_mesh(mesh: Mesh | None) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Mesh | None:
    return _MESH


def dp_axes() -> tuple[str, ...]:
    """Mesh axes that carry data parallelism (('pod','data') when present)."""
    if _MESH is None:
        return ()
    return tuple(a for a in _MESH.axis_names if a in ("pod", "data"))


def batch_spec(*rest) -> P:
    """PartitionSpec with the batch dim over all DP axes."""
    axes = dp_axes()
    lead = axes if len(axes) > 1 else (axes[0] if axes else None)
    return P(lead, *rest)


def shard(x, spec: P):
    """with_sharding_constraint that no-ops without an installed mesh."""
    if _MESH is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


def shard_batch(x, *rest):
    return shard(x, batch_spec(*rest))


def strip_axis(spec: P, axis: str = "data") -> P:
    """Remove one mesh axis from every dim of a PartitionSpec."""
    out = []
    for e in tuple(spec):
        if e == axis:
            out.append(None)
        elif isinstance(e, tuple):
            kept = tuple(a for a in e if a != axis)
            out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        else:
            out.append(e)
    return P(*out)


def degather(params, param_specs, mesh, quantized: bool = False):
    """ZeRO-3 gather-at-use: constrain FSDP-sharded parameters to their
    TP-only layout inside the step.  XLA materializes the all-gather here and
    reduce-scatters gradients through the transpose; parameter *storage* at
    the jit boundary stays fully sharded.

    ``quantized=True`` compresses the weight all-gather to int8 (+bf16
    per-row scales): quantize while sharded, gather the int8 payload, and
    dequantize locally — halving the dominant FSDP collective volume.
    Gradients flow through the straight-through dequant (the quantizer is
    treated as identity for the backward, standard QAT practice)."""
    if mesh is None:
        return params

    def gathered(spec):
        return strip_axis(spec, "data")

    def leaf(x, spec):
        if not quantized or x.ndim < 2 or x.dtype == jnp.float32:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, gathered(spec))
            )
        from repro.optim.compression import dequantize, quantize

        @jax.custom_vjp
        def q_gather(w):
            q = quantize(w, block=w.shape[-1])
            qv = jax.lax.with_sharding_constraint(
                q.q, NamedSharding(mesh, gathered(spec))
            )
            sc = jax.lax.with_sharding_constraint(
                q.scale,
                NamedSharding(mesh, P(*tuple(gathered(spec))[:-1], None)),
            )
            return dequantize(type(q)(qv, sc, q.block), w.dtype)

        def fwd(w):
            return q_gather(w), None

        def bwd(_, g):  # straight-through: grads reshard via the transpose
            return (jax.lax.with_sharding_constraint(
                g, NamedSharding(mesh, P(*tuple(spec)))
            ),)

        q_gather.defvjp(fwd, bwd)
        return q_gather(x)

    return jax.tree.map(leaf, params, param_specs,
                        is_leaf=lambda x: hasattr(x, "shape"))
