"""Moving-window latency profiler (paper §6.1).

For each worker, the coordinator records the round-trip time between sending
an iterate and receiving a response; the worker records its own
compute-only time and ships it back inside the response.  The profiler takes
the worker-recorded time as a computation-latency sample and the difference
as a communication-latency sample, computes mean/variance over a moving time
window (samples older than ``window`` seconds are dropped), and hands the
moments to the load-balancing optimizer, which fits gamma distributions
(paper footnote 12).
"""

from __future__ import annotations

import bisect
import dataclasses
from collections import deque

import numpy as np

from repro.latency.model import GammaParams


@dataclasses.dataclass(frozen=True)
class LatencySample:
    worker: int
    t_recorded: float  # wall/sim time at which the sample was taken
    round_trip: float  # coordinator-observed send->receive latency
    compute: float  # worker-reported compute latency
    load: float  # computational load (c) of the task that produced this


@dataclasses.dataclass
class WorkerStats:
    e_comm: float
    v_comm: float
    e_comp: float
    v_comp: float
    mean_load: float
    num_samples: int

    @property
    def e_total(self) -> float:
        return self.e_comm + self.e_comp

    def comm_gamma(self) -> GammaParams:
        return GammaParams.from_mean_var(max(self.e_comm, 1e-12), max(self.v_comm, 1e-18))

    def comp_gamma_per_unit(self) -> GammaParams:
        """Gamma of the *per-unit-load* computation latency (for what-if
        re-scaling by the optimizer, paper §6.2 linearisation)."""
        e = max(self.e_comp / max(self.mean_load, 1e-12), 1e-12)
        v = max(self.v_comp / max(self.mean_load, 1e-12) ** 2, 1e-18)
        return GammaParams.from_mean_var(e, v)


@dataclasses.dataclass(frozen=True)
class ProfilerMoments:
    """Per-worker moment arrays ([N]) for the load-balancing optimizer."""

    e_comm: np.ndarray
    v_comm: np.ndarray
    e_comp: np.ndarray
    v_comp: np.ndarray
    mean_load: np.ndarray
    num_samples: np.ndarray


class MomentBuffer:
    """Task-slot sample buffers behind the engines' §6.1 profiler view.

    Dense ``[S, N, T]`` arrays indexed by the *iteration that started the
    task* (each (scenario, worker, iteration) starts at most one task and
    its completion is observed at most once, so the slot is unique).  The
    moments are computed by the shared jittable kernel
    (:func:`repro.lb.jit_optimizer.window_moments`), which every engine —
    the scalar ``TrainingSimulator`` at ``S = 1``, the batched host
    convergence loop, and the fused scan (tracing the same function
    inline) — uses with identical slot layouts, so the §6 optimizer sees
    bit-identical moments in all three.  This replaces the deque-based
    :class:`LatencyProfiler` in the load-balancing loop; the deque
    profiler remains the general-purpose telemetry view.
    """

    def __init__(self, num_scenarios: int, num_workers: int, capacity: int):
        shape = (num_scenarios, num_workers, capacity)
        self.t_rec = np.zeros(shape)
        self.comm = np.zeros(shape)
        self.comp = np.zeros(shape)
        self.valid = np.zeros(shape, dtype=bool)

    def record(self, s, workers, titers, t_recorded, round_trip, compute) -> None:
        """Record observed completions (parallel arrays; ``s`` broadcastable).

        The communication sample is ``max(round_trip - compute, 0)`` —
        the §6.1 split of coordinator-observed round-trip time into the
        worker-reported compute part and the rest."""
        self.t_rec[s, workers, titers] = t_recorded
        self.comm[s, workers, titers] = np.maximum(
            np.asarray(round_trip, np.float64) - np.asarray(compute, np.float64), 0.0
        )
        self.comp[s, workers, titers] = compute
        self.valid[s, workers, titers] = True

    def moments(
        self,
        now: np.ndarray,
        *,
        window: float | None = None,
        since: np.ndarray | None = None,
    ):
        """(e_comm, v_comm, e_comp, v_comp, counts) at per-scenario ``now``.

        Delegates to the shared jitted window-moments kernel; a worker
        with zero in-window samples reports count 0 (callers gate on it
        like ``LatencyProfiler.moment_arrays`` returning None).  ``since``
        (per-scenario, optional) drops samples recorded before it — the
        churn re-profiling cutoff (see
        :func:`repro.lb.jit_optimizer.window_moments`)."""
        from jax.experimental import enable_x64

        from repro.lb.jit_optimizer import PROFILER_WINDOW, _window_moments_jitted

        fn = _window_moments_jitted(
            float(PROFILER_WINDOW if window is None else window),
            with_since=since is not None,
        )
        args = (self.t_rec, self.comm, self.comp, self.valid, np.asarray(now))
        if since is not None:
            args = args + (np.asarray(since),)
        with enable_x64():
            out = fn(*args)
        e_comm, v_comm, e_comp, v_comp, cnt = (np.asarray(a) for a in out)
        return e_comm, v_comm, e_comp, v_comp, cnt


class LatencyProfiler:
    """Per-worker moving-window mean/variance of comm and comp latency."""

    def __init__(self, num_workers: int, *, window: float = 10.0):
        self.num_workers = num_workers
        self.window = window
        self._samples: list[deque] = [deque() for _ in range(num_workers)]

    def record(self, sample: LatencySample) -> None:
        if not (0 <= sample.worker < self.num_workers):
            raise ValueError(f"worker {sample.worker} out of range")
        comm = max(sample.round_trip - sample.compute, 0.0)
        dq = self._samples[sample.worker]
        dq.append((sample.t_recorded, comm, sample.compute, sample.load))

    def record_batch(
        self,
        workers: np.ndarray,
        t_recorded: np.ndarray,
        round_trip: np.ndarray,
        compute: np.ndarray,
        load: np.ndarray,
    ) -> int:
        """Bulk-insert samples from parallel arrays (batched-trace feed).

        Entries are sorted by ``t_recorded`` per worker so the moving-window
        eviction of :meth:`stats` keeps working; NaN entries (tasks that
        never ran in a replayed trace) are dropped.  Returns the number of
        samples recorded.
        """
        workers = np.asarray(workers, dtype=np.int64)
        load = np.broadcast_to(np.asarray(load, dtype=np.float64), workers.shape).ravel()
        workers = workers.ravel()
        t_recorded = np.asarray(t_recorded, dtype=np.float64).ravel()
        round_trip = np.asarray(round_trip, dtype=np.float64).ravel()
        compute = np.asarray(compute, dtype=np.float64).ravel()
        ok = ~(np.isnan(t_recorded) | np.isnan(round_trip) | np.isnan(compute))
        if not ok.all():
            workers, t_recorded, round_trip, compute, load = (
                a[ok] for a in (workers, t_recorded, round_trip, compute, load)
            )
        if workers.size == 0:
            return 0
        if np.any((workers < 0) | (workers >= self.num_workers)):
            raise ValueError("worker index out of range in batch")
        comm = np.maximum(round_trip - compute, 0.0)
        order = np.lexsort((t_recorded, workers))
        workers, t_recorded, comm, compute, load = (
            a[order] for a in (workers, t_recorded, comm, compute, load)
        )
        bounds = np.searchsorted(workers, np.arange(self.num_workers + 1))
        for i in range(self.num_workers):
            lo, hi = bounds[i], bounds[i + 1]
            if lo < hi:
                dq = self._samples[i]
                dq.extend(
                    zip(t_recorded[lo:hi], comm[lo:hi], compute[lo:hi], load[lo:hi])
                )
                if len(dq) > hi - lo and dq[-(hi - lo) - 1][0] > t_recorded[lo]:
                    # batch starts before existing samples (e.g. a second
                    # replayed scenario whose clock restarts at 0): re-sort so
                    # _evict's front-only scan keeps seeing time order
                    ordered = sorted(dq)
                    dq.clear()
                    dq.extend(ordered)
        return int(workers.size)

    def _evict(self, worker: int, now: float) -> None:
        dq = self._samples[worker]
        cutoff = now - self.window
        while dq and dq[0][0] < cutoff:
            dq.popleft()

    def stats(self, worker: int, now: float) -> WorkerStats | None:
        self._evict(worker, now)
        dq = self._samples[worker]
        if len(dq) == 0:
            return None
        arr = np.asarray(dq, dtype=np.float64)  # [k, 4]
        comm, comp, load = arr[:, 1], arr[:, 2], arr[:, 3]
        return WorkerStats(
            e_comm=float(comm.mean()),
            v_comm=float(comm.var()) if len(dq) > 1 else 1e-12,
            e_comp=float(comp.mean()),
            v_comp=float(comp.var()) if len(dq) > 1 else 1e-12,
            mean_load=float(load.mean()),
            num_samples=len(dq),
        )

    def all_stats(self, now: float) -> dict[int, WorkerStats]:
        out = {}
        for i in range(self.num_workers):
            s = self.stats(i, now)
            if s is not None:
                out[i] = s
        return out

    def moment_arrays(self, now: float) -> "ProfilerMoments" | None:
        """All workers' moments as [N] arrays (the §6.2 optimizer feed).

        Returns None unless every worker has at least one in-window sample —
        the same gate the load-balancing loop applies before invoking
        Algorithm 1.  Both the scalar simulator and the batched convergence
        engine build their :class:`~repro.lb.optimizer.OptimizerInputs` from
        this method so the two paths see identical moments.
        """
        stats = self.all_stats(now)
        if len(stats) < self.num_workers:
            return None
        idx = range(self.num_workers)
        return ProfilerMoments(
            e_comm=np.array([stats[i].e_comm for i in idx]),
            v_comm=np.array([stats[i].v_comm for i in idx]),
            e_comp=np.array([stats[i].e_comp for i in idx]),
            v_comp=np.array([stats[i].v_comp for i in idx]),
            mean_load=np.array([stats[i].mean_load for i in idx]),
            num_samples=np.array([stats[i].num_samples for i in idx]),
        )
