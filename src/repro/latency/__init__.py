"""Latency modeling (paper §3-§4): per-worker gamma comm/comp latency,
bursts, Monte-Carlo order statistics, event-driven iterative simulation,
and the moving-window profiler used by the load balancer."""

from repro.latency.model import (
    GammaParams,
    WorkerLatencyModel,
    ClusterLatencyModel,
    fit_gamma,
    make_heterogeneous_cluster,
    make_paper_artificial_cluster,
    clear_slowdowns,
)
from repro.latency.order_stats import (
    predict_order_statistic,
    predict_order_statistics_iid,
    empirical_order_statistic,
)
from repro.latency.event_sim import EventDrivenSimulator, simulate_iteration_times
from repro.latency.profiler import LatencyProfiler, LatencySample

__all__ = [
    "GammaParams",
    "WorkerLatencyModel",
    "ClusterLatencyModel",
    "fit_gamma",
    "make_heterogeneous_cluster",
    "make_paper_artificial_cluster",
    "predict_order_statistic",
    "predict_order_statistics_iid",
    "empirical_order_statistic",
    "EventDrivenSimulator",
    "simulate_iteration_times",
    "LatencyProfiler",
    "LatencySample",
]
