"""Per-worker latency model (paper §3).

The latency of worker ``i`` for an iteration with ``b`` bytes communicated and
computational load ``c`` is modeled as

    X_i^{(b,c)} = Y_i^{(b)} + Z_i^{(c)}

where ``Y_i`` (communication) and ``Z_i`` (computation) are *independent but
not identically distributed* gamma random variables — each worker has its own
parameters (paper Fig. 3, footnote 7).  The mean computation latency scales
linearly with the computational load ``c`` (paper Fig. 1), and workers
additionally experience *bursts* of elevated latency (paper §3.2, Fig. 4):
multiplicative slowdowns that arrive as a Poisson process and last an
exponentially distributed duration.

All sampling is numpy-based (host control plane — this is Tier-2/Tier-3 code;
no JAX device state is touched here).
"""

from __future__ import annotations

import dataclasses
import math

from collections.abc import Sequence
import numpy as np

def comp_latency_expr(comp_unit_draw, load, slowdown, factor):
    """THE §3 computation-latency expression: ``unit * load * slowdown * factor``.

    The multiplication order is load-bearing for bit-exact replay: every
    consumer — :meth:`FleetTraces.task_latency_parts` (batched numpy),
    :meth:`FleetTraces.scalar_task_latency` (scalar), and the fused-scan
    body (:mod:`repro.experiments.fused`, jnp) — must evaluate it through
    this one function so the grouping cannot drift.
    """
    return comp_unit_draw * load * slowdown * factor


# ---------------------------------------------------------------------------
# Gamma parameterisation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GammaParams:
    """Gamma distribution parameterised by (shape, scale).

    Paper footnote 12: a gamma r.v. with mean ``e`` and variance ``v`` has
    shape ``e^2/v`` and scale ``v/e``.
    """

    shape: float
    scale: float

    @property
    def mean(self) -> float:
        return self.shape * self.scale

    @property
    def var(self) -> float:
        return self.shape * self.scale**2

    @staticmethod
    def from_mean_var(mean: float, var: float) -> "GammaParams":
        if mean <= 0:
            raise ValueError(f"gamma mean must be positive, got {mean}")
        var = max(var, 1e-18)  # degenerate -> near-deterministic
        return GammaParams(shape=mean * mean / var, scale=var / mean)

    def sample(self, rng: np.random.Generator, size=None) -> np.ndarray:
        return rng.gamma(self.shape, self.scale, size=size)


def fit_gamma(samples: Sequence[float]) -> GammaParams:
    """Method-of-moments gamma fit (what the profiler/optimizer use)."""
    arr = np.asarray(samples, dtype=np.float64)
    if arr.size == 0:
        raise ValueError("cannot fit gamma to zero samples")
    mean = float(arr.mean())
    var = float(arr.var()) if arr.size > 1 else 1e-12
    return GammaParams.from_mean_var(mean, max(var, 1e-18))


# ---------------------------------------------------------------------------
# Worker / cluster models
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BurstState:
    """Multiplicative latency burst (paper §3.2 / Fig. 4)."""

    active: bool = False
    factor: float = 1.0
    ends_at: float = 0.0


@dataclasses.dataclass
class WorkerLatencyModel:
    """Latency model for a single worker.

    ``comm`` models Y_i for the reference byte count ``b_ref``;
    ``comp_per_unit`` models Z_i *per unit of computational load*, so that the
    expected computation latency for load ``c`` is ``c * comp_per_unit.mean``
    (paper Fig. 1: mean and variance scale linearly/quadratically with load —
    the per-unit gamma is scaled by ``c``, giving mean ∝ c and var ∝ c²,
    matching the linearisation in paper §6.2).
    """

    comm: GammaParams
    comp_per_unit: GammaParams
    burst_rate: float = 0.0  # bursts per second (Poisson)
    burst_factor_mean: float = 1.12  # paper Fig. 4: ~12% slowdown
    burst_duration_mean: float = 60.0  # paper Fig. 4: ~1 minute
    # artificial *persistent* slowdown (paper §7.2 artificial scenario)
    slowdown: float = 1.0

    _burst: BurstState = dataclasses.field(default_factory=BurstState)

    # -- burst process --------------------------------------------------
    def _start_burst(self, now: float, rng: np.random.Generator) -> float:
        factor = 1.0 + rng.exponential(self.burst_factor_mean - 1.0)
        self._burst = BurstState(
            active=True,
            factor=factor,
            ends_at=now + rng.exponential(self.burst_duration_mean),
        )
        return factor

    def _burst_factor(self, now: float, rng: np.random.Generator) -> float:
        if self._burst.active:
            if now >= self._burst.ends_at:
                # the idle-gap clock restarts when the burst ends
                self._last_query_t = self._burst.ends_at
                self._burst = BurstState()
            else:
                return self._burst.factor
        if self.burst_rate > 0.0:
            last = getattr(self, "_last_query_t", None)
            if last is None:
                # stationary start: the fleet was running long before t=0, so
                # a worker is mid-burst with probability dur/(idle+dur); the
                # residual duration is again exponential (memorylessness)
                self._last_query_t = now
                lam_m = self.burst_rate * self.burst_duration_mean
                if rng.random() < lam_m / (1.0 + lam_m):
                    return self._start_burst(now, rng)
                return 1.0
            # burst arrivals sampled lazily at query time by thinning the
            # Poisson process over the elapsed idle gap (memorylessness)
            p_start = 1.0 - math.exp(-self.burst_rate * max(now - last, 0.0))
            self._last_query_t = now
            if rng.random() < p_start:
                return self._start_burst(now, rng)
        return 1.0

    # -- sampling --------------------------------------------------------
    def sample_comm(self, rng: np.random.Generator) -> float:
        return float(self.comm.sample(rng))

    def sample_comp(self, c: float, rng: np.random.Generator, now: float = 0.0) -> float:
        base = float(self.comp_per_unit.sample(rng)) * c
        return base * self.slowdown * self._burst_factor(now, rng)

    def sample_total(self, c: float, rng: np.random.Generator, now: float = 0.0) -> float:
        return self.sample_comm(rng) + self.sample_comp(c, rng, now)

    # -- analytic moments (for the optimizer's e'_{X,i}) -----------------
    def mean_total(self, c: float) -> float:
        return self.comm.mean + self.comp_per_unit.mean * c * self.slowdown


@dataclasses.dataclass
class ClusterLatencyModel:
    """A set of per-worker latency models (non-i.i.d. across workers)."""

    workers: list  # list[WorkerLatencyModel]
    seed: int = 0

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)

    @property
    def num_workers(self) -> int:
        return len(self.workers)

    def sample_all(self, c: float, now: float = 0.0) -> np.ndarray:
        """One latency draw per worker for a single iteration."""
        return np.array(
            [w.sample_total(c, self.rng, now) for w in self.workers], dtype=np.float64
        )

    def sample_matrix(self, c: float, iters: int) -> np.ndarray:
        """[iters, N] latency draws (steady state, no cross-iteration state)."""
        return np.stack([self.sample_all(c) for _ in range(iters)])


# ---------------------------------------------------------------------------
# Cluster factories (calibrated to the paper's measurements)
# ---------------------------------------------------------------------------

#: Approximate latency ranges from paper Table 1 (AWS logistic regression):
#: comm 1e-4..6e-4 s, comp 1.1e-3..1.3e-3 s.
AWS_LOGREG_COMM = (1e-4, 6e-4)
AWS_LOGREG_COMP = (1.1e-3, 1.3e-3)
#: eX3 logistic regression: comm 0.2e-5..3e-5 s, comp 1.8e-3..2.5e-3 s.
EX3_LOGREG_COMM = (0.2e-5, 3e-5)
EX3_LOGREG_COMP = (1.8e-3, 2.5e-3)


def make_heterogeneous_cluster(
    num_workers: int,
    *,
    comm_range=AWS_LOGREG_COMM,
    comp_range=AWS_LOGREG_COMP,
    load_unit: float = 1.0,
    cv_comm: float = 0.35,
    cv_comp: float = 0.15,
    burst_rate: float = 1.0 / 90.0,
    seed: int = 0,
) -> ClusterLatencyModel:
    """Cluster with per-worker means drawn uniformly from the paper's measured
    ranges and fixed coefficients of variation — i.e. independent but NOT
    identically distributed workers (the paper's central modeling point)."""
    rng = np.random.default_rng(seed)
    workers = []
    for _ in range(num_workers):
        e_y = rng.uniform(*comm_range)
        e_z = rng.uniform(*comp_range) / load_unit  # per unit load
        workers.append(
            WorkerLatencyModel(
                comm=GammaParams.from_mean_var(e_y, (cv_comm * e_y) ** 2),
                comp_per_unit=GammaParams.from_mean_var(e_z, (cv_comp * e_z) ** 2),
                burst_rate=burst_rate,
            )
        )
    return ClusterLatencyModel(workers=workers, seed=seed + 1)


def make_paper_artificial_cluster(
    num_workers: int = 49,
    *,
    comp_mean: float = 2.0e-3,
    comm_mean: float = 1.0e-5,
    cv_comm: float = 0.3,
    cv_comp: float = 0.1,
    load_unit: float = 1.0,
    seed: int = 0,
) -> ClusterLatencyModel:
    """The paper's §7.2 artificial scenario on eX3: worker ``i`` (1-based) is
    slowed by a factor ``1 + (i/N)*0.4``; the benchmark driver removes the
    slowdown of the last 10 workers after 1 s via a timed event.

    ``load_unit`` calibrates the model: a task with computational load
    ``c = load_unit`` has expected computation latency ``comp_mean`` (the
    paper's Table-1 eX3 values) — pass the typical per-task ops count."""
    rng = np.random.default_rng(seed)
    del rng
    e_z = comp_mean / load_unit
    workers = []
    for i in range(1, num_workers + 1):
        w = WorkerLatencyModel(
            comm=GammaParams.from_mean_var(comm_mean, (cv_comm * comm_mean) ** 2),
            comp_per_unit=GammaParams.from_mean_var(e_z, (cv_comp * e_z) ** 2),
            burst_rate=0.0,
            slowdown=1.0 + (i / num_workers) * 0.4,
        )
        workers.append(w)
    return ClusterLatencyModel(workers=workers, seed=seed + 1)


def clear_slowdowns(cluster: ClusterLatencyModel, worker_indices) -> None:
    """Remove the artificial slowdown of the given workers (paper §7.2:
    'we remove this artificial latency for workers 40 through 49 after one
    second')."""
    for i in worker_indices:
        cluster.workers[i].slowdown = 1.0


# ---------------------------------------------------------------------------
# Elastic-fleet churn: time-varying slowdowns and worker death/join
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ChurnSchedule:
    """Piecewise-constant fleet state over time: slowdowns and liveness.

    ``times`` ([C], strictly increasing, all > 0) are the change boundaries,
    shared across scenarios; row ``r`` of ``slowdown`` / ``alive``
    ([C+1, N]) applies on ``[times[r-1], times[r])`` (row 0 before the
    first boundary).  When a :class:`FleetTraces` carries a schedule, its
    slowdown rows *replace* the static ``traces.slowdown`` field — the
    row is looked up at each task's **start** time, the same query time
    convention as the §3.2 burst factor.

    Liveness is sampled once per iteration at assignment time: a worker
    dead at the iteration's assign discards any in-flight task (no stale
    completion, no cache write, no profiler sample, no latency
    attribution), starts nothing, consumes no draws, and has its §5 cache
    entries cleared; the wait-for-w order statistic uses
    ``w_eff = min(w, #alive)``.  A revived or late-joining worker re-enters
    idle with empty cache slots at its next assign.  Every row must keep at
    least one worker alive.

    A *trivial* schedule (``ChurnSchedule.static(traces.slowdown)``) gathers
    the same float64 slowdowns through the same
    :func:`comp_latency_expr`, so replay through the churn-aware paths is
    bit-identical to the static paths (pinned in ``tests/test_churn.py``).
    """

    times: np.ndarray  # [C] float64, strictly increasing, > 0
    slowdown: np.ndarray  # [C+1, N] float64
    alive: np.ndarray  # [C+1, N] bool

    def __post_init__(self):
        self.times = np.asarray(self.times, dtype=np.float64).reshape(-1)
        self.slowdown = np.asarray(self.slowdown, dtype=np.float64)
        self.alive = np.asarray(self.alive, dtype=bool)
        C = self.times.shape[0]
        if self.slowdown.ndim != 2 or self.alive.shape != self.slowdown.shape:
            raise ValueError(
                "slowdown and alive must both be [C+1, N] with matching shapes"
            )
        if self.slowdown.shape[0] != C + 1:
            raise ValueError(
                f"{C} boundaries need {C + 1} state rows, "
                f"got {self.slowdown.shape[0]}"
            )
        if C and (not np.all(np.diff(self.times) > 0) or self.times[0] <= 0.0):
            raise ValueError("churn times must be strictly increasing and > 0")
        if not np.all(np.isfinite(self.slowdown)) or np.any(self.slowdown <= 0):
            raise ValueError("churn slowdowns must be finite and > 0")
        if not np.all(self.alive.any(axis=1)):
            raise ValueError("every churn row must keep at least one worker alive")

    @property
    def num_workers(self) -> int:
        return self.slowdown.shape[1]

    @classmethod
    def static(cls, slowdown) -> "ChurnSchedule":
        """The trivial all-alive schedule replaying a static slowdown field."""
        sd = np.asarray(slowdown, dtype=np.float64).reshape(1, -1)
        return cls(
            times=np.zeros(0), slowdown=sd, alive=np.ones_like(sd, dtype=bool)
        )

    def row_at(self, t):
        """Row index active at time(s) ``t`` (scalar or array)."""
        return np.searchsorted(self.times, t, side="right")

    def slowdown_at(self, start: np.ndarray) -> np.ndarray:
        """Per-task slowdown at start times ``start`` ([S, N] -> [S, N])."""
        rows = self.row_at(np.asarray(start, dtype=np.float64))
        return self.slowdown[rows, np.arange(self.slowdown.shape[1])[None, :]]

    def alive_at(self, t) -> np.ndarray:
        """Liveness row(s) at time(s) ``t`` (scalar -> [N], [S] -> [S, N])."""
        return self.alive[self.row_at(t)]

    def boundary_before(self, row) -> np.ndarray:
        """Time of the boundary that opened ``row`` (-inf for row 0).

        This is the ``since`` cutoff the §6 profiler re-reads its window
        from after a churn event — samples recorded under the previous
        fleet state are excluded from the moments.
        """
        row = np.asarray(row)
        padded = np.concatenate(([-np.inf], self.times))
        return padded[row]


@dataclasses.dataclass(frozen=True)
class SlowdownRemoval:
    """Structured §7.2 timed event: clear some workers' slowdown at ``time``.

    Callable on a :class:`ClusterLatencyModel` (the live-sampling path), and
    convertible to a :class:`ChurnSchedule` row flip (the trace-replay
    path) — which is what lets
    :class:`~repro.cluster.simulator.TrainingSimulator` replay the paper's
    artificial-slowdown scenario from pre-sampled traces instead of
    refusing it.
    """

    time: float
    workers: tuple  # 0-based worker indices

    def __call__(self, cluster: "ClusterLatencyModel") -> None:
        clear_slowdowns(cluster, self.workers)


def churn_from_removals(
    slowdown: np.ndarray, removals: Sequence[SlowdownRemoval]
) -> ChurnSchedule:
    """Build the churn schedule equivalent to applying ``removals`` to a
    fleet with static per-worker ``slowdown`` (all workers alive)."""
    sd = np.asarray(slowdown, dtype=np.float64)
    events = sorted(removals, key=lambda e: e.time)
    times = np.array([e.time for e in events], dtype=np.float64)
    rows = [sd.copy()]
    for ev in events:
        nxt = rows[-1].copy()
        nxt[list(ev.workers)] = 1.0
        rows.append(nxt)
    sd_rows = np.stack(rows)
    return ChurnSchedule(
        times=times, slowdown=sd_rows, alive=np.ones_like(sd_rows, dtype=bool)
    )


def paper_artificial_churn(
    num_workers: int = 49, *, remove_at: float = 60.0, num_removed: int = 10
) -> ChurnSchedule:
    """The §7.2 artificial scenario as a churn schedule: worker ``i``
    (1-based) slowed by ``1 + (i/N)*0.4``, the last ``num_removed`` workers'
    slowdown removed at ``remove_at`` (paper: after one minute)."""
    sd = 1.0 + (np.arange(1, num_workers + 1) / num_workers) * 0.4
    removal = SlowdownRemoval(
        time=remove_at,
        workers=tuple(range(num_workers - num_removed, num_workers)),
    )
    return churn_from_removals(sd, [removal])


# ---------------------------------------------------------------------------
# Batched fleet sampling (scenario sweeps, §7)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetTraces:
    """Pre-sampled latency traces for a whole (scenario x worker x task) grid.

    ``comm[s, i, k]`` / ``comp_unit[s, i, k]`` hold the k-th communication /
    per-unit-load computation draw of worker ``i`` in scenario ``s``; a worker
    consumes its draws sequentially, one per *started* task, so the same
    arrays can be replayed bit-exactly through the scalar event loop
    (:class:`repro.latency.event_sim.EventDrivenSimulator` with a
    ``latency_provider``) and the batched sweep engine
    (:func:`repro.experiments.sweep.replay_batch`).

    Bursts (paper §3.2) are pre-sampled as non-overlapping multiplicative
    windows per (scenario, worker): an alternating renewal process with
    Exp(1/rate) idle gaps and Exp(duration_mean) burst durations; the factor
    of each window is ``1 + Exp(factor_mean - 1)``.  ``burst_factor_at``
    looks up the active factor at arbitrary times.  Windows are sampled out
    to a finite time horizon; beyond it the factor is 1.0.
    """

    comm: np.ndarray  # [S, N, K] float64
    comp_unit: np.ndarray  # [S, N, K] float64, per unit computational load
    slowdown: np.ndarray  # [N] persistent per-worker slowdown factors
    burst_start: np.ndarray  # [S, N, M] (M == 0 when burst-free)
    burst_end: np.ndarray  # [S, N, M]
    burst_factor: np.ndarray  # [S, N, M]
    seed: int = 0
    #: optional elastic-fleet schedule; when set, its slowdown rows replace
    #: the static ``slowdown`` field (looked up at task start time) and its
    #: liveness rows drive the per-iteration worker mask in every engine
    churn: ChurnSchedule | None = None

    @property
    def num_scenarios(self) -> int:
        return self.comm.shape[0]

    @property
    def num_workers(self) -> int:
        return self.comm.shape[1]

    @property
    def horizon(self) -> int:
        return self.comm.shape[2]

    @property
    def has_bursts(self) -> bool:
        return self.burst_start.shape[2] > 0

    def burst_factor_at(self, t: np.ndarray) -> np.ndarray:
        """Active burst factor at times ``t`` ([S, N] -> [S, N])."""
        if not self.has_bursts:
            return np.ones_like(t, dtype=np.float64)
        tt = np.asarray(t, dtype=np.float64)[:, :, None]
        active = (self.burst_start <= tt) & (tt < self.burst_end)
        # windows are non-overlapping, so at most one factor is selected
        return np.where(active, self.burst_factor, 1.0).max(axis=2)

    def _scalar_burst_factor(self, s: int, i: int, t: float) -> float:
        """Same lookup as :meth:`burst_factor_at` for one (scenario, worker)."""
        if not self.has_bursts:
            return 1.0
        starts = self.burst_start[s, i]
        idx = int(np.searchsorted(starts, t, side="right")) - 1
        if idx >= 0 and t < self.burst_end[s, i, idx]:
            return float(self.burst_factor[s, i, idx])
        return 1.0

    def task_latency_parts(
        self, k: np.ndarray, start: np.ndarray, loads
    ) -> tuple:
        """(comm, comp) latency of each worker's next task across scenarios.

        ``k`` [S, N] is the per-(scenario, worker) draw index, ``start``
        [S, N] the task start time, ``loads`` a scalar / [N] / [S, N]
        computational load.  The arithmetic (order of multiplications) is
        kept identical to :meth:`scalar_task_latency` so the batched and
        scalar replay paths are bit-exact.
        """
        S, N, K = self.comm.shape
        k = np.asarray(k)
        if k.size and int(k.max()) >= K:
            # same invariant as scalar_task_latency: silently reusing the
            # last draw would fake a deterministic worker
            raise ValueError(
                f"trace draws exhausted (draw {int(k.max())} of horizon {K}); "
                "sample a longer fleet"
            )
        s_idx = np.arange(S)[:, None]
        n_idx = np.arange(N)[None, :]
        kk = k
        factor = self.burst_factor_at(start)
        slowdown = (
            self.slowdown[None, :]
            if self.churn is None
            else self.churn.slowdown_at(start)
        )
        comp = comp_latency_expr(
            self.comp_unit[s_idx, n_idx, kk],
            np.asarray(loads, dtype=np.float64),
            slowdown,
            factor,
        )
        return self.comm[s_idx, n_idx, kk], comp

    def task_latency(self, k: np.ndarray, start: np.ndarray, loads) -> np.ndarray:
        """Total latency (comm + comp) of each worker's next task."""
        comm, comp = self.task_latency_parts(k, start, loads)
        return comm + comp

    def scalar_task_latency(
        self, scenario: int, worker: int, k: int, start: float, load: float
    ) -> tuple:
        """(comm, comp) of one draw — THE scalar counterpart of
        :meth:`task_latency_parts`.

        Every scalar consumer (``scalar_latency_provider``,
        ``TraceLatencySource``) must go through this method: replay
        bit-exactness depends on the multiplication order matching the
        batched path, which is why the formula itself lives in exactly one
        place (:func:`comp_latency_expr`).

        Raises when a worker's draw stream is exhausted; silently reusing
        the last draw would fake a deterministic worker.
        """
        if k >= self.horizon:
            raise ValueError(
                f"trace draws exhausted for worker {worker} "
                f"(horizon {self.horizon}); sample a longer fleet"
            )
        factor = self._scalar_burst_factor(scenario, worker, start)
        if self.churn is None:
            slowdown = self.slowdown[worker]
        else:
            slowdown = self.churn.slowdown[int(self.churn.row_at(start)), worker]
        comp = comp_latency_expr(
            self.comp_unit[scenario, worker, k], load, slowdown, factor
        )
        return self.comm[scenario, worker, k], comp

    def scalar_latency_provider(self, scenario: int, loads):
        """A ``(worker, start_time) -> latency`` closure consuming this
        scenario's draws in per-worker order — plug into
        :class:`~repro.latency.event_sim.EventDrivenSimulator` to replay a
        pre-sampled trace through the scalar event loop."""
        loads_arr = np.broadcast_to(
            np.asarray(loads, dtype=np.float64), (self.num_workers,)
        ).copy() if np.ndim(loads) <= 1 else np.asarray(
            loads[scenario], dtype=np.float64
        )
        counters = np.zeros(self.num_workers, dtype=np.int64)

        def provider(i: int, start: float) -> float:
            k = int(counters[i])
            counters[i] += 1
            comm, comp = self.scalar_task_latency(scenario, i, k, start, loads_arr[i])
            return comm + comp

        return provider

    def with_churn(self, churn: ChurnSchedule | None) -> "FleetTraces":
        """Copy of these traces carrying ``churn`` (None clears it).

        The schedule's slowdown rows replace the static ``slowdown`` field
        for every latency lookup, so a trivial schedule built via
        ``ChurnSchedule.static(traces.slowdown)`` replays bit-identically.
        """
        if churn is not None and churn.num_workers != self.num_workers:
            raise ValueError(
                f"churn schedule has {churn.num_workers} workers "
                f"but the traces have {self.num_workers}"
            )
        return dataclasses.replace(self, churn=churn)


def sample_fleet(
    cluster: ClusterLatencyModel,
    n_scenarios: int,
    horizon: int,
    *,
    burst_rate: float | None = None,
    burst_factor_mean: float | None = None,
    burst_duration_mean: float | None = None,
    time_horizon: float | None = None,
    load_hint: float = 1.0,
    max_bursts: int = 4096,
    seed: int = 0,
) -> FleetTraces:
    """Draw the full (scenario x worker x task) latency grid at once.

    Vectorizes the §3 gamma model and the §3.2 burst process over
    ``n_scenarios`` independent scenarios and ``horizon`` tasks per worker
    (one task per iteration is started at most, so ``horizon`` equal to the
    number of iterations always suffices).  The per-worker gamma parameters,
    slowdowns, and (unless overridden) burst parameters come from
    ``cluster``; the ``burst_*`` keywords override them uniformly, which is
    how the sweep driver realizes different burst *regimes* from one
    cluster.

    ``time_horizon`` bounds the burst renewal process in simulated seconds;
    if omitted it is estimated as twice the expected makespan of ``horizon``
    tasks of load ``load_hint`` on the slowest worker.
    """
    N = cluster.num_workers
    rng = np.random.default_rng(seed)
    shape_c = np.array([w.comm.shape for w in cluster.workers])
    scale_c = np.array([w.comm.scale for w in cluster.workers])
    shape_z = np.array([w.comp_per_unit.shape for w in cluster.workers])
    scale_z = np.array([w.comp_per_unit.scale for w in cluster.workers])
    slowdown = np.array([w.slowdown for w in cluster.workers], dtype=np.float64)

    comm = rng.gamma(shape_c[None, :, None], scale_c[None, :, None],
                     size=(n_scenarios, N, horizon))
    comp_unit = rng.gamma(shape_z[None, :, None], scale_z[None, :, None],
                          size=(n_scenarios, N, horizon))

    rates = np.array(
        [burst_rate if burst_rate is not None else w.burst_rate for w in cluster.workers],
        dtype=np.float64,
    )
    f_means = np.array(
        [
            burst_factor_mean if burst_factor_mean is not None else w.burst_factor_mean
            for w in cluster.workers
        ],
        dtype=np.float64,
    )
    d_means = np.array(
        [
            burst_duration_mean
            if burst_duration_mean is not None
            else w.burst_duration_mean
        for w in cluster.workers
        ],
        dtype=np.float64,
    )

    if np.all(rates <= 0.0):
        empty = np.zeros((n_scenarios, N, 0))
        return FleetTraces(comm, comp_unit, slowdown, empty, empty.copy(),
                           empty.copy(), seed=seed)

    if time_horizon is None:
        per_task = np.max(
            (shape_c * scale_c) + (shape_z * scale_z) * load_hint * slowdown
        )
        # bursts inflate the realized makespan; without accounting for the
        # duty cycle a high-duty regime would outrun its sampled windows and
        # silently turn calm in the tail
        duty = (rates * d_means) / (1.0 + rates * d_means)
        inflation = 1.0 + float(np.max(duty * (f_means - 1.0)))
        time_horizon = 2.0 * horizon * float(per_task) * inflation
    max_rate = float(np.max(rates))
    mean_cycle = 1.0 / max_rate + float(np.min(d_means))
    M = int(math.ceil(1.5 * time_horizon / mean_cycle) + 6)
    if M > max_bursts:
        # clamping would silently leave the tail of the run burst-free —
        # the same failure the time_horizon inflation above guards against
        raise ValueError(
            f"{M} burst windows needed to cover time_horizon={time_horizon:g} "
            f"but max_bursts={max_bursts}; raise max_bursts or pass a smaller "
            "time_horizon"
        )

    safe_scale = np.where(rates > 0.0, 1.0 / np.maximum(rates, 1e-30), 1.0)
    gaps = rng.exponential(safe_scale[None, :, None], size=(n_scenarios, N, M))
    gaps = np.where(rates[None, :, None] > 0.0, gaps, np.inf)
    durations = rng.exponential(d_means[None, :, None], size=(n_scenarios, N, M))
    # stationary start: the fleet was running long before t=0, so a worker
    # begins mid-burst with probability dur/(idle+dur) — zero out the first
    # idle gap for those (scenario, worker) pairs; the first duration draw is
    # the residual burst length by memorylessness.  Without this, sweeps much
    # shorter than 1/rate would never see a burst at all.
    duty = (rates * d_means) / (1.0 + rates * d_means)
    in_burst_at_0 = rng.random((n_scenarios, N)) < duty[None, :]
    gaps[:, :, 0] = np.where(in_burst_at_0, 0.0, gaps[:, :, 0])
    factors = 1.0 + rng.exponential(
        np.maximum(f_means - 1.0, 1e-12)[None, :, None], size=(n_scenarios, N, M)
    )
    # alternating renewal: start_m = sum_{j<=m} gap_j + sum_{j<m} dur_j
    starts = np.cumsum(gaps, axis=2) + np.cumsum(durations, axis=2) - durations
    ends = starts + durations
    return FleetTraces(comm, comp_unit, slowdown, starts, ends, factors, seed=seed)
