"""Per-worker latency model (paper §3).

The latency of worker ``i`` for an iteration with ``b`` bytes communicated and
computational load ``c`` is modeled as

    X_i^{(b,c)} = Y_i^{(b)} + Z_i^{(c)}

where ``Y_i`` (communication) and ``Z_i`` (computation) are *independent but
not identically distributed* gamma random variables — each worker has its own
parameters (paper Fig. 3, footnote 7).  The mean computation latency scales
linearly with the computational load ``c`` (paper Fig. 1), and workers
additionally experience *bursts* of elevated latency (paper §3.2, Fig. 4):
multiplicative slowdowns that arrive as a Poisson process and last an
exponentially distributed duration.

All sampling is numpy-based (host control plane — this is Tier-2/Tier-3 code;
no JAX device state is touched here).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Gamma parameterisation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GammaParams:
    """Gamma distribution parameterised by (shape, scale).

    Paper footnote 12: a gamma r.v. with mean ``e`` and variance ``v`` has
    shape ``e^2/v`` and scale ``v/e``.
    """

    shape: float
    scale: float

    @property
    def mean(self) -> float:
        return self.shape * self.scale

    @property
    def var(self) -> float:
        return self.shape * self.scale**2

    @staticmethod
    def from_mean_var(mean: float, var: float) -> "GammaParams":
        if mean <= 0:
            raise ValueError(f"gamma mean must be positive, got {mean}")
        var = max(var, 1e-18)  # degenerate -> near-deterministic
        return GammaParams(shape=mean * mean / var, scale=var / mean)

    def sample(self, rng: np.random.Generator, size=None) -> np.ndarray:
        return rng.gamma(self.shape, self.scale, size=size)


def fit_gamma(samples: Sequence[float]) -> GammaParams:
    """Method-of-moments gamma fit (what the profiler/optimizer use)."""
    arr = np.asarray(samples, dtype=np.float64)
    if arr.size == 0:
        raise ValueError("cannot fit gamma to zero samples")
    mean = float(arr.mean())
    var = float(arr.var()) if arr.size > 1 else 1e-12
    return GammaParams.from_mean_var(mean, max(var, 1e-18))


# ---------------------------------------------------------------------------
# Worker / cluster models
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BurstState:
    """Multiplicative latency burst (paper §3.2 / Fig. 4)."""

    active: bool = False
    factor: float = 1.0
    ends_at: float = 0.0


@dataclasses.dataclass
class WorkerLatencyModel:
    """Latency model for a single worker.

    ``comm`` models Y_i for the reference byte count ``b_ref``;
    ``comp_per_unit`` models Z_i *per unit of computational load*, so that the
    expected computation latency for load ``c`` is ``c * comp_per_unit.mean``
    (paper Fig. 1: mean and variance scale linearly/quadratically with load —
    the per-unit gamma is scaled by ``c``, giving mean ∝ c and var ∝ c²,
    matching the linearisation in paper §6.2).
    """

    comm: GammaParams
    comp_per_unit: GammaParams
    burst_rate: float = 0.0  # bursts per second (Poisson)
    burst_factor_mean: float = 1.12  # paper Fig. 4: ~12% slowdown
    burst_duration_mean: float = 60.0  # paper Fig. 4: ~1 minute
    # artificial *persistent* slowdown (paper §7.2 artificial scenario)
    slowdown: float = 1.0

    _burst: BurstState = dataclasses.field(default_factory=BurstState)

    # -- burst process --------------------------------------------------
    def _burst_factor(self, now: float, rng: np.random.Generator) -> float:
        if self._burst.active:
            if now >= self._burst.ends_at:
                self._burst = BurstState()
            else:
                return self._burst.factor
        if self.burst_rate > 0.0:
            # Probability a burst starts within one iteration-ish window; we
            # sample burst arrivals lazily at query time using the gap since
            # the last query (memorylessness of the Poisson process).
            gap = getattr(self, "_last_query_gap", 1.0)
            p_start = 1.0 - math.exp(-self.burst_rate * max(gap, 1e-9))
            if rng.random() < p_start:
                factor = 1.0 + rng.exponential(self.burst_factor_mean - 1.0)
                self._burst = BurstState(
                    active=True,
                    factor=factor,
                    ends_at=now + rng.exponential(self.burst_duration_mean),
                )
                return factor
        return 1.0

    # -- sampling --------------------------------------------------------
    def sample_comm(self, rng: np.random.Generator) -> float:
        return float(self.comm.sample(rng))

    def sample_comp(self, c: float, rng: np.random.Generator, now: float = 0.0) -> float:
        base = float(self.comp_per_unit.sample(rng)) * c
        return base * self.slowdown * self._burst_factor(now, rng)

    def sample_total(self, c: float, rng: np.random.Generator, now: float = 0.0) -> float:
        return self.sample_comm(rng) + self.sample_comp(c, rng, now)

    # -- analytic moments (for the optimizer's e'_{X,i}) -----------------
    def mean_total(self, c: float) -> float:
        return self.comm.mean + self.comp_per_unit.mean * c * self.slowdown


@dataclasses.dataclass
class ClusterLatencyModel:
    """A set of per-worker latency models (non-i.i.d. across workers)."""

    workers: list  # list[WorkerLatencyModel]
    seed: int = 0

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)

    @property
    def num_workers(self) -> int:
        return len(self.workers)

    def sample_all(self, c: float, now: float = 0.0) -> np.ndarray:
        """One latency draw per worker for a single iteration."""
        return np.array(
            [w.sample_total(c, self.rng, now) for w in self.workers], dtype=np.float64
        )

    def sample_matrix(self, c: float, iters: int) -> np.ndarray:
        """[iters, N] latency draws (steady state, no cross-iteration state)."""
        return np.stack([self.sample_all(c) for _ in range(iters)])


# ---------------------------------------------------------------------------
# Cluster factories (calibrated to the paper's measurements)
# ---------------------------------------------------------------------------

#: Approximate latency ranges from paper Table 1 (AWS logistic regression):
#: comm 1e-4..6e-4 s, comp 1.1e-3..1.3e-3 s.
AWS_LOGREG_COMM = (1e-4, 6e-4)
AWS_LOGREG_COMP = (1.1e-3, 1.3e-3)
#: eX3 logistic regression: comm 0.2e-5..3e-5 s, comp 1.8e-3..2.5e-3 s.
EX3_LOGREG_COMM = (0.2e-5, 3e-5)
EX3_LOGREG_COMP = (1.8e-3, 2.5e-3)


def make_heterogeneous_cluster(
    num_workers: int,
    *,
    comm_range=AWS_LOGREG_COMM,
    comp_range=AWS_LOGREG_COMP,
    load_unit: float = 1.0,
    cv_comm: float = 0.35,
    cv_comp: float = 0.15,
    burst_rate: float = 1.0 / 90.0,
    seed: int = 0,
) -> ClusterLatencyModel:
    """Cluster with per-worker means drawn uniformly from the paper's measured
    ranges and fixed coefficients of variation — i.e. independent but NOT
    identically distributed workers (the paper's central modeling point)."""
    rng = np.random.default_rng(seed)
    workers = []
    for _ in range(num_workers):
        e_y = rng.uniform(*comm_range)
        e_z = rng.uniform(*comp_range) / load_unit  # per unit load
        workers.append(
            WorkerLatencyModel(
                comm=GammaParams.from_mean_var(e_y, (cv_comm * e_y) ** 2),
                comp_per_unit=GammaParams.from_mean_var(e_z, (cv_comp * e_z) ** 2),
                burst_rate=burst_rate,
            )
        )
    return ClusterLatencyModel(workers=workers, seed=seed + 1)


def make_paper_artificial_cluster(
    num_workers: int = 49,
    *,
    comp_mean: float = 2.0e-3,
    comm_mean: float = 1.0e-5,
    cv_comm: float = 0.3,
    cv_comp: float = 0.1,
    load_unit: float = 1.0,
    seed: int = 0,
) -> ClusterLatencyModel:
    """The paper's §7.2 artificial scenario on eX3: worker ``i`` (1-based) is
    slowed by a factor ``1 + (i/N)*0.4``; the benchmark driver removes the
    slowdown of the last 10 workers after 1 s via a timed event.

    ``load_unit`` calibrates the model: a task with computational load
    ``c = load_unit`` has expected computation latency ``comp_mean`` (the
    paper's Table-1 eX3 values) — pass the typical per-task ops count."""
    rng = np.random.default_rng(seed)
    del rng
    e_z = comp_mean / load_unit
    workers = []
    for i in range(1, num_workers + 1):
        w = WorkerLatencyModel(
            comm=GammaParams.from_mean_var(comm_mean, (cv_comm * comm_mean) ** 2),
            comp_per_unit=GammaParams.from_mean_var(e_z, (cv_comp * e_z) ** 2),
            burst_rate=0.0,
            slowdown=1.0 + (i / num_workers) * 0.4,
        )
        workers.append(w)
    return ClusterLatencyModel(workers=workers, seed=seed + 1)


def clear_slowdowns(cluster: ClusterLatencyModel, worker_indices) -> None:
    """Remove the artificial slowdown of the given workers (paper §7.2:
    'we remove this artificial latency for workers 40 through 49 after one
    second')."""
    for i in worker_indices:
        cluster.workers[i].slowdown = 1.0
