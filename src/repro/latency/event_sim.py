"""Event-driven simulation of iterative w-of-N computations (paper §4.2).

Workers are two-state (idle/busy) with a FILO task queue of length 1.  At the
start of each iteration the coordinator assigns a task to every *idle* worker
(busy workers keep their queued task replaced — queue length 1, freshest
iterate wins); the iteration completes once ``w`` tasks assigned *this*
iteration have finished.  A priority queue (heapq) maps workers to their next
busy→idle transition, which is exactly the paper's event-driven strategy.

This simulator is the engine behind both:
  * latency prediction for iterative computations (paper Fig. 6), and
  * the load-balancing optimizer's estimate of the contribution h(p)
    (paper §6.2, via :meth:`EventDrivenSimulator.estimate_contribution`).
"""

from __future__ import annotations

import dataclasses
import heapq

from collections.abc import Callable, Sequence
import numpy as np

from repro.latency.model import ClusterLatencyModel


@dataclasses.dataclass
class SimResult:
    iteration_times: np.ndarray  # [iters] completion time of each iteration
    fresh_counts: np.ndarray  # [iters] # of workers that returned fresh results
    participation: np.ndarray  # [N] fraction of iterations each worker was fresh in


class EventDrivenSimulator:
    """Simulate ``T_w^{(1)}..T_w^{(ell)}`` for a cluster latency model.

    ``loads`` gives the per-worker computational load (c_i); with
    load balancing this is ``base_load_i / p_i`` (one subpartition per task).
    """

    def __init__(
        self,
        cluster: ClusterLatencyModel | None,
        loads: Sequence[float],
        *,
        with_bursts: bool = False,
        latency_provider: Callable[[int, float], float] | None = None,
    ):
        if cluster is None and latency_provider is None:
            raise ValueError("need a cluster model or a latency_provider")
        if cluster is not None and len(loads) != cluster.num_workers:
            raise ValueError("loads must have one entry per worker")
        self.cluster = cluster
        self.loads = np.asarray(loads, dtype=np.float64)
        self.with_bursts = with_bursts
        #: optional trace replay: ``(worker, start_time) -> latency`` consuming
        #: pre-sampled draws (e.g. ``FleetTraces.scalar_latency_provider``)
        #: instead of sampling the cluster model live.
        self.latency_provider = latency_provider

    @property
    def num_workers(self) -> int:
        return self.cluster.num_workers if self.cluster is not None else len(self.loads)

    def run(
        self,
        w: int,
        num_iterations: int,
        *,
        margin: float = 0.0,
        churn=None,
    ) -> SimResult:
        """``churn`` (a :class:`~repro.latency.model.ChurnSchedule`) applies
        the elastic-fleet semantics: liveness is sampled at each iteration's
        assignment time, dead workers discard in-flight tasks (the heap event
        is invalidated via a per-worker generation counter) and the wait uses
        ``w_eff = min(w, #alive)``."""
        n = self.num_workers
        if not (1 <= w <= n):
            raise ValueError(f"w={w} not in 1..{n}")
        rng = self.cluster.rng if self.cluster is not None else None
        now = 0.0
        # (finish_time, worker, iteration_of_task, generation)
        heap: list = []
        busy_until = np.zeros(n)  # next idle time per worker
        queued_iter = -np.ones(n, dtype=np.int64)  # iteration idx of queued task
        gen = np.zeros(n, dtype=np.int64)  # bumped to discard in-flight events
        iteration_times = np.zeros(num_iterations)
        fresh_counts = np.zeros(num_iterations, dtype=np.int64)
        fresh_mask_accum = np.zeros(n, dtype=np.int64)

        def sample_latency(i: int, start: float) -> float:
            if self.latency_provider is not None:
                return float(self.latency_provider(i, start))
            wk = self.cluster.workers[i]
            if self.with_bursts:
                return wk.sample_total(self.loads[i], rng, now=start)
            return wk.sample_comm(rng) + float(
                wk.comp_per_unit.sample(rng)
            ) * self.loads[i] * wk.slowdown

        for t in range(num_iterations):
            if churn is None:
                alive = None
                w_eff = w
            else:
                alive = churn.alive_at(now)
                for i in range(n):
                    if not alive[i] and (busy_until[i] > now or queued_iter[i] >= 0):
                        # dead at assignment: discard the in-flight task and
                        # the queued one — its completion never happens
                        gen[i] += 1
                        busy_until[i] = now
                        queued_iter[i] = -1
                w_eff = min(w, int(alive.sum()))
            # assign a task for iteration t to every worker: idle workers start
            # immediately; busy workers get their length-1 queue overwritten.
            for i in range(n):
                if alive is not None and not alive[i]:
                    continue
                if busy_until[i] <= now:
                    fin = now + sample_latency(i, now)
                    busy_until[i] = fin
                    heapq.heappush(heap, (fin, i, t, int(gen[i])))
                else:
                    queued_iter[i] = t
            fresh = 0
            fresh_this_iter = np.zeros(n, dtype=bool)
            deadline = np.inf
            iter_start = now
            while fresh < w_eff or (heap and heap[0][0] <= deadline):
                if not heap:
                    break
                fin, i, task_iter, g = heapq.heappop(heap)
                if g != gen[i]:
                    continue  # discarded by a death event; must not touch `now`
                if fin > deadline:
                    # margin expired: put the event back and stop collecting
                    heapq.heappush(heap, (fin, i, task_iter, g))
                    break
                now = fin
                # worker i becomes idle; start its queued task if any
                if queued_iter[i] >= 0:
                    nfin = now + sample_latency(i, now)
                    busy_until[i] = nfin
                    heapq.heappush(heap, (nfin, i, int(queued_iter[i]), int(gen[i])))
                    queued_iter[i] = -1
                else:
                    busy_until[i] = now
                if task_iter == t:
                    fresh += 1
                    fresh_this_iter[i] = True
                    if fresh == w_eff and margin > 0.0:
                        # paper §5.1: wait `margin` (e.g. 2%) longer than this
                        # iteration took so far, collecting stragglers.
                        deadline = now + margin * (now - iter_start)
                    elif fresh == w_eff:
                        break
            iteration_times[t] = now
            fresh_counts[t] = fresh
            fresh_mask_accum += fresh_this_iter
        return SimResult(
            iteration_times=iteration_times,
            fresh_counts=fresh_counts,
            participation=fresh_mask_accum / max(num_iterations, 1),
        )

    # -- load-balancer support (paper §6.2) ------------------------------
    def estimate_participation(
        self, w: int, *, num_iterations: int = 100, margin: float = 0.02
    ) -> np.ndarray:
        """u_i(p): fraction of iterations each worker delivers a fresh result
        in, for the loads this simulator was built with (paper §6.2 —
        'u_i can be estimated via event-driven simulations')."""
        return self.run(w, num_iterations, margin=margin).participation


def estimate_contribution(
    cluster: ClusterLatencyModel,
    w: int,
    subpartitions: Sequence[int],
    samples_per_worker: Sequence[int],
    base_loads: Sequence[float],
    *,
    num_iterations: int = 100,
    margin: float = 0.02,
) -> tuple[float, np.ndarray]:
    """h(p) = Σ_i u_i(p)·n_i/(p_i·n) estimated via event-driven simulation.

    ``base_loads[i]`` is worker i's computational load when p_i = 1 (i.e. the
    whole local dataset in one task); per-task load is base_loads[i]/p_i."""
    p = np.asarray(subpartitions, dtype=np.float64)
    n_i = np.asarray(samples_per_worker, dtype=np.float64)
    n = float(n_i.sum())
    loads = np.asarray(base_loads, dtype=np.float64) / np.maximum(p, 1.0)
    sim = EventDrivenSimulator(cluster, loads)
    u = sim.estimate_participation(w, num_iterations=num_iterations, margin=margin)
    h = float(np.sum(u * n_i / (p * n)))
    return h, u


def simulate_iteration_times(
    cluster: ClusterLatencyModel,
    w: int,
    c: float,
    num_iterations: int,
    *,
    margin: float = 0.0,
) -> np.ndarray:
    """Cumulative completion times T_w^(1..ell) for uniform load c."""
    sim = EventDrivenSimulator(cluster, [c] * cluster.num_workers)
    return sim.run(w, num_iterations, margin=margin).iteration_times


def naive_iteration_times(
    cluster: ClusterLatencyModel,
    w: int,
    c: float,
    num_iterations: int,
) -> np.ndarray:
    """The §4.1 model applied per-iteration (no cross-iteration worker state):
    iteration latency = fresh i.i.d.-in-time draw of the w-th order statistic.
    Underestimates for w < N (paper Fig. 6)."""
    lat = cluster.sample_matrix(c, num_iterations)
    per_iter = np.partition(lat, w - 1, axis=1)[:, w - 1]
    return np.cumsum(per_iter)
