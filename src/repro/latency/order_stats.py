"""Order-statistic latency prediction (paper §4.1).

Predict the latency of the ``w``-th fastest worker out of ``N`` via Monte
Carlo integration: draw one latency per worker per trial and select the
``w``-th smallest (``np.partition`` — linear-time selection, the numpy
analogue of Quickselect).  Also provides the commonly-adopted-but-wrong
i.i.d. predictor (global mean/variance pooled across workers) that the paper
shows mispredicts badly (Fig. 5).
"""

from __future__ import annotations

import numpy as np

from repro.latency.model import ClusterLatencyModel, GammaParams


def predict_order_statistic(
    cluster: ClusterLatencyModel,
    w: int,
    c: float,
    *,
    num_trials: int = 1000,
    seed: int = 0,
) -> float:
    """E[latency of the w-th fastest of N workers], Monte Carlo, non-iid."""
    if not (1 <= w <= cluster.num_workers):
        raise ValueError(f"w={w} out of range 1..{cluster.num_workers}")
    rng = np.random.default_rng(seed)
    n = cluster.num_workers
    comm_shape = np.array([wk.comm.shape for wk in cluster.workers])
    comm_scale = np.array([wk.comm.scale for wk in cluster.workers])
    comp_shape = np.array([wk.comp_per_unit.shape for wk in cluster.workers])
    comp_scale = np.array([wk.comp_per_unit.scale for wk in cluster.workers])
    slow = np.array([wk.slowdown for wk in cluster.workers])
    # vectorised over (trials, workers); bursts excluded (steady state, §4.1)
    y = rng.gamma(comm_shape, comm_scale, size=(num_trials, n))
    z = rng.gamma(comp_shape, comp_scale, size=(num_trials, n)) * c * slow
    total = y + z
    kth = np.partition(total, w - 1, axis=1)[:, w - 1]
    return float(kth.mean())


def predict_order_statistics_all(
    cluster: ClusterLatencyModel,
    c: float,
    *,
    num_trials: int = 1000,
    seed: int = 0,
) -> np.ndarray:
    """E[latency of w-th fastest] for every w=1..N in one pass."""
    rng = np.random.default_rng(seed)
    n = cluster.num_workers
    comm_shape = np.array([wk.comm.shape for wk in cluster.workers])
    comm_scale = np.array([wk.comm.scale for wk in cluster.workers])
    comp_shape = np.array([wk.comp_per_unit.shape for wk in cluster.workers])
    comp_scale = np.array([wk.comp_per_unit.scale for wk in cluster.workers])
    slow = np.array([wk.slowdown for wk in cluster.workers])
    y = rng.gamma(comm_shape, comm_scale, size=(num_trials, n))
    z = rng.gamma(comp_shape, comp_scale, size=(num_trials, n)) * c * slow
    return np.sort(y + z, axis=1).mean(axis=0)


def predict_order_statistics_iid(
    cluster: ClusterLatencyModel,
    c: float,
    *,
    num_trials: int = 1000,
    seed: int = 0,
) -> np.ndarray:
    """The i.i.d. baseline predictor (paper Fig. 5): every worker is modeled
    by a single gamma with the *pooled* mean and variance."""
    rng = np.random.default_rng(seed)
    n = cluster.num_workers
    # pooled moments of the total latency across workers
    means = np.array([wk.comm.mean + wk.comp_per_unit.mean * c * wk.slowdown
                      for wk in cluster.workers])
    vars_ = np.array([wk.comm.var + wk.comp_per_unit.var * (c * wk.slowdown) ** 2
                      for wk in cluster.workers])
    pooled_mean = means.mean()
    # law of total variance: within-worker + between-worker
    pooled_var = vars_.mean() + means.var()
    g = GammaParams.from_mean_var(pooled_mean, pooled_var)
    total = rng.gamma(g.shape, g.scale, size=(num_trials, n))
    return np.sort(total, axis=1).mean(axis=0)


def empirical_order_statistic(latency_matrix: np.ndarray) -> np.ndarray:
    """Empirical E[w-th order statistic] from an [iters, N] latency matrix."""
    return np.sort(latency_matrix, axis=1).mean(axis=0)
